//! Ergonomic construction API for Aquas-IR functions.
//!
//! The builder keeps a stack of open regions; `for_loop`/`if_else` take
//! closures that build the nested body. All workload programs
//! (`crate::workloads`) and ISAX descriptions are written against this.

// Panic-free audit (robustness): emission is split into `emit1` (always
// produces a value) / `emit0` (never does), so no site unwraps an Option
// that is Some by construction. Arity misuse of the *builder API itself*
// (mismatched yields, unclosed regions) still asserts — that is a bug in
// the calling Rust code, not hostile input.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::interface::cache::CacheHint;
use crate::interface::model::InterfaceId;
use crate::interface::TransactionKind;
use crate::ir::func::{BufferDecl, BufferId, BufferKind, Func, Region, Value};
use crate::ir::ops::{CmpPred, Op, OpKind};
use crate::ir::types::Type;
use crate::runtime::DType;

/// Builder over a [`Func`] under construction.
pub struct FuncBuilder {
    func: Func,
    /// Stack of open regions; ops append to the top.
    stack: Vec<Region>,
}

impl FuncBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        Self { func: Func::new(name), stack: vec![Region::default()] }
    }

    /// Add a scalar function parameter.
    pub fn param(&mut self, ty: Type) -> Value {
        let v = self.func.new_value(ty);
        self.func.params.push(v);
        v
    }

    /// Declare a global-memory symbol.
    pub fn global(&mut self, name: &str, elem: DType, len: usize, hint: CacheHint) -> BufferId {
        self.global_at(name, elem, len, hint, self.next_base_addr())
    }

    /// Declare a global-memory symbol at an explicit base address.
    pub fn global_at(
        &mut self,
        name: &str,
        elem: DType,
        len: usize,
        hint: CacheHint,
        base_addr: u64,
    ) -> BufferId {
        self.func.add_buffer(BufferDecl {
            name: name.into(),
            kind: BufferKind::Global,
            elem,
            len,
            hint,
            base_addr,
        })
    }

    /// Declare an ISAX scratchpad.
    pub fn scratchpad(&mut self, name: &str, elem: DType, len: usize, banks: usize) -> BufferId {
        self.func.add_buffer(BufferDecl {
            name: name.into(),
            kind: BufferKind::Scratchpad { banks },
            elem,
            len,
            hint: CacheHint::Unknown,
            base_addr: 0,
        })
    }

    fn next_base_addr(&self) -> u64 {
        // Pack globals contiguously, 64B-aligned, starting at 0x1000.
        let mut addr = 0x1000u64;
        for b in &self.func.buffers {
            if matches!(b.kind, BufferKind::Global) {
                addr = addr.max(b.base_addr + b.size_bytes() as u64);
            }
        }
        addr.next_multiple_of(64)
    }

    // ----- op emission helpers -------------------------------------------

    /// The open region ops append to. The stack is non-empty by
    /// construction (`new` seeds it; pops pair with pushes), so the
    /// fallback re-opening a region is unreachable in practice — it
    /// exists to keep emission total under the unwrap/expect deny.
    fn top(&mut self) -> &mut Region {
        if self.stack.is_empty() {
            self.stack.push(Region::default());
        }
        let last = self.stack.len() - 1;
        &mut self.stack[last]
    }

    /// Emit an op that produces exactly one value of type `ty`.
    fn emit1(&mut self, kind: OpKind, operands: Vec<Value>, ty: Type) -> Value {
        let out = self.func.new_value(ty);
        let op = Op::new(kind, operands, vec![out]);
        let opref = self.func.add_op(op);
        self.top().ops.push(opref);
        out
    }

    /// Emit an op that produces no values.
    fn emit0(&mut self, kind: OpKind, operands: Vec<Value>) {
        let op = Op::new(kind, operands, vec![]);
        let opref = self.func.add_op(op);
        self.top().ops.push(opref);
    }

    pub fn const_i(&mut self, v: i64) -> Value {
        self.emit1(OpKind::ConstI(v), vec![], Type::Int)
    }

    pub fn const_f(&mut self, v: f64) -> Value {
        self.emit1(OpKind::ConstF(v), vec![], Type::Float)
    }

    fn binop(&mut self, kind: OpKind, a: Value, b: Value) -> Value {
        let ty = self.func.value_type(a);
        self.emit1(kind, vec![a, b], ty)
    }

    pub fn add(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Add, a, b)
    }
    pub fn sub(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Sub, a, b)
    }
    pub fn mul(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Mul, a, b)
    }
    pub fn div(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Div, a, b)
    }
    pub fn rem(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Rem, a, b)
    }
    pub fn shl(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Shl, a, b)
    }
    pub fn shr(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Shr, a, b)
    }
    pub fn and(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::And, a, b)
    }
    pub fn or(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Or, a, b)
    }
    pub fn xor(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Xor, a, b)
    }
    pub fn min(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Min, a, b)
    }
    pub fn max(&mut self, a: Value, b: Value) -> Value {
        self.binop(OpKind::Max, a, b)
    }

    pub fn neg(&mut self, a: Value) -> Value {
        let ty = self.func.value_type(a);
        self.emit1(OpKind::Neg, vec![a], ty)
    }

    pub fn sqrt(&mut self, a: Value) -> Value {
        self.emit1(OpKind::Sqrt, vec![a], Type::Float)
    }

    pub fn exp(&mut self, a: Value) -> Value {
        self.emit1(OpKind::Exp, vec![a], Type::Float)
    }

    pub fn powi(&mut self, a: Value, e: u32) -> Value {
        self.emit1(OpKind::Powi(e), vec![a], Type::Float)
    }

    pub fn to_float(&mut self, a: Value) -> Value {
        self.emit1(OpKind::ToFloat, vec![a], Type::Float)
    }

    pub fn to_int(&mut self, a: Value) -> Value {
        self.emit1(OpKind::ToInt, vec![a], Type::Int)
    }

    pub fn cmp(&mut self, pred: CmpPred, a: Value, b: Value) -> Value {
        self.emit1(OpKind::Cmp(pred), vec![a, b], Type::Int)
    }

    pub fn select(&mut self, cond: Value, a: Value, b: Value) -> Value {
        let ty = self.func.value_type(a);
        self.emit1(OpKind::Select, vec![cond, a, b], ty)
    }

    // ----- memory ----------------------------------------------------------

    fn elem_ty(&self, buf: BufferId) -> Type {
        match self.func.buffer(buf).elem {
            DType::F32 => Type::Float,
            DType::I32 => Type::Int,
        }
    }

    pub fn load(&mut self, buf: BufferId, index: Value) -> Value {
        let ty = self.elem_ty(buf);
        self.emit1(OpKind::Load(buf), vec![index], ty)
    }

    pub fn store(&mut self, buf: BufferId, index: Value, value: Value) {
        self.emit0(OpKind::Store(buf), vec![index, value]);
    }

    pub fn transfer(
        &mut self,
        dst: BufferId,
        dst_off: Value,
        src: BufferId,
        src_off: Value,
        size: usize,
    ) {
        self.emit0(OpKind::Transfer { dst, src, size }, vec![dst_off, src_off]);
    }

    pub fn fetch(&mut self, buf: BufferId, index: Value) -> Value {
        let ty = self.elem_ty(buf);
        self.emit1(OpKind::Fetch(buf), vec![index], ty)
    }

    pub fn read_smem(&mut self, buf: BufferId, index: Value) -> Value {
        let ty = self.elem_ty(buf);
        self.emit1(OpKind::ReadSmem(buf), vec![index], ty)
    }

    pub fn write_smem(&mut self, buf: BufferId, index: Value, value: Value) {
        self.emit0(OpKind::WriteSmem(buf), vec![index, value]);
    }

    pub fn read_irf(&mut self, reg: u8) -> Value {
        self.emit1(OpKind::ReadIrf(reg), vec![], Type::Int)
    }

    pub fn write_irf(&mut self, reg: u8, value: Value) {
        self.emit0(OpKind::WriteIrf(reg), vec![value]);
    }

    pub fn copy(
        &mut self,
        itfc: InterfaceId,
        dst: BufferId,
        dst_off: Value,
        src: BufferId,
        src_off: Value,
        size: usize,
        kind: TransactionKind,
    ) {
        self.emit0(OpKind::Copy { itfc, dst, src, size, kind }, vec![dst_off, src_off]);
    }

    pub fn intrinsic(
        &mut self,
        name: &str,
        operands: Vec<Value>,
        has_result: bool,
    ) -> Option<Value> {
        if has_result {
            Some(self.emit1(OpKind::Intrinsic(name.into()), operands, Type::Int))
        } else {
            self.emit0(OpKind::Intrinsic(name.into()), operands);
            None
        }
    }

    // ----- control flow ------------------------------------------------------

    /// Build `for iv in (lb..ub).step_by(step)` with loop-carried values.
    /// `body` receives (builder, iv, carried) and returns the yielded
    /// values; the loop op's results (final carried values) are returned.
    pub fn for_loop<F>(
        &mut self,
        lb: Value,
        ub: Value,
        step: Value,
        init: &[Value],
        body: F,
    ) -> Vec<Value>
    where
        F: FnOnce(&mut Self, Value, &[Value]) -> Vec<Value>,
    {
        let iv = self.func.new_value(Type::Int);
        let carried: Vec<Value> = init
            .iter()
            .map(|&v| {
                let ty = self.func.value_type(v);
                self.func.new_value(ty)
            })
            .collect();
        let mut params = vec![iv];
        params.extend(&carried);
        self.stack.push(Region { params, ops: Vec::new() });

        let yields = body(self, iv, &carried);
        assert_eq!(yields.len(), init.len(), "for: yield arity != iter_args arity");
        self.emit0(OpKind::Yield, yields);

        let region = self.stack.pop().unwrap_or_default();
        let results: Vec<Value> = init
            .iter()
            .map(|&v| {
                let ty = self.func.value_type(v);
                self.func.new_value(ty)
            })
            .collect();
        let mut operands = vec![lb, ub, step];
        operands.extend_from_slice(init);
        let mut op = Op::new(OpKind::For, operands, results.clone());
        op.regions.push(region);
        let opref = self.func.add_op(op);
        self.top().ops.push(opref);
        results
    }

    /// Convenience: constant-bound loop without carried values.
    pub fn for_range<F>(&mut self, lb: i64, ub: i64, step: i64, body: F)
    where
        F: FnOnce(&mut Self, Value),
    {
        let lbv = self.const_i(lb);
        let ubv = self.const_i(ub);
        let stepv = self.const_i(step);
        self.for_loop(lbv, ubv, stepv, &[], |b, iv, _| {
            body(b, iv);
            vec![]
        });
    }

    /// Build `if cond { then } else { els }`; arm closures return yielded
    /// values (same arity/types); returns the if results.
    pub fn if_else<FT, FE>(&mut self, cond: Value, then: FT, els: FE) -> Vec<Value>
    where
        FT: FnOnce(&mut Self) -> Vec<Value>,
        FE: FnOnce(&mut Self) -> Vec<Value>,
    {
        self.stack.push(Region::default());
        let tvals = then(self);
        self.emit0(OpKind::Yield, tvals.clone());
        let then_region = self.stack.pop().unwrap_or_default();

        self.stack.push(Region::default());
        let evals = els(self);
        assert_eq!(tvals.len(), evals.len(), "if: arm yield arity mismatch");
        self.emit0(OpKind::Yield, evals);
        let else_region = self.stack.pop().unwrap_or_default();

        let results: Vec<Value> = tvals
            .iter()
            .map(|&v| {
                let ty = self.func.value_type(v);
                self.func.new_value(ty)
            })
            .collect();
        let mut op = Op::new(OpKind::If, vec![cond], results.clone());
        op.regions.push(then_region);
        op.regions.push(else_region);
        let opref = self.func.add_op(op);
        self.top().ops.push(opref);
        results
    }

    /// Finish with `return values` and produce the function.
    pub fn finish(mut self, values: &[Value]) -> Func {
        self.emit0(OpKind::Return, values.to_vec());
        assert_eq!(self.stack.len(), 1, "unclosed regions at finish()");
        self.func.entry = self.stack.pop().unwrap_or_default();
        self.func
    }

    /// Access the function under construction (e.g. for type queries).
    pub fn func(&self) -> &Func {
        &self.func
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn builds_nested_loop_with_carried_sum() {
        let mut b = FuncBuilder::new("sum");
        let buf = b.global("x", DType::I32, 16, CacheHint::Unknown);
        let zero = b.const_i(0);
        let lb = b.const_i(0);
        let ub = b.const_i(16);
        let one = b.const_i(1);
        let sums = b.for_loop(lb, ub, one, &[zero], |b, iv, carried| {
            let x = b.load(buf, iv);
            let s = b.add(carried[0], x);
            vec![s]
        });
        let f = b.finish(&sums);
        assert_eq!(f.entry.ops.len(), 6); // consts + for + return
        assert_eq!(f.count_ops(|k| matches!(k, OpKind::For)), 1);
        assert_eq!(f.count_ops(|k| matches!(k, OpKind::Load(_))), 1);
    }

    #[test]
    fn if_else_results_typed() {
        let mut b = FuncBuilder::new("sel");
        let p = b.param(Type::Int);
        let zero = b.const_i(0);
        let c = b.cmp(CmpPred::Gt, p, zero);
        let r = b.if_else(
            c,
            |b| vec![b.const_f(1.0)],
            |b| vec![b.const_f(2.0)],
        );
        let f = b.finish(&r);
        assert_eq!(f.value_type(r[0]), Type::Float);
        assert_eq!(f.count_ops(|k| matches!(k, OpKind::If)), 1);
    }
}
