//! Reference interpreter for Aquas-IR.
//!
//! Executes a function at *any* level (functional transfers, architectural
//! copies, temporal issue/wait pairs all move the same bytes) against a
//! memory image. This gives the semantic ground truth used to prove that
//! synthesis transformations (§4.3) and compiler rewrites (§5.3) preserve
//! behaviour, and to check the ISAX datapaths against the AOT Pallas
//! artifacts (see `rust/tests/`).
//!
//! This module is the *tree-walking* engine: it re-dispatches on `OpKind`
//! per executed op against a register map. Since PR 4 it serves as the
//! differential oracle for the compiled register-bytecode VM
//! ([`crate::ir::vm`]), which executes the same semantics at
//! compile-once/run-many speed. Traced execution (`run_traced` with a
//! live trace sink) always routes through this engine.
//!
//! [`Memory`] is shared by both engines: a flat *typed* arena — one
//! `Vec<f64>` or `Vec<i64>` per buffer, no per-element tag — so bulk
//! copies are slice operations and host read-back needs no per-element
//! match.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::interface::dmasim::IssueClock;
use crate::interface::model::InterfaceSet;
use crate::ir::func::{BufferId, Func, Region, Value};
use crate::ir::ops::{CmpPred, Op, OpKind};
use crate::runtime::DType;

/// A runtime scalar.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Val {
    I(i64),
    F(f64),
}

impl Val {
    pub fn as_i(&self) -> Result<i64> {
        match self {
            Val::I(v) => Ok(*v),
            Val::F(v) => Err(Error::Ir(format!("expected int, got float {v}"))),
        }
    }

    pub fn as_f(&self) -> Result<f64> {
        match self {
            Val::F(v) => Ok(*v),
            Val::I(v) => Err(Error::Ir(format!("expected float, got int {v}"))),
        }
    }
}

/// Typed storage for one buffer: float buffers hold `f64` (the
/// interpreter's float width), int buffers hold `i64`. Flat and untagged —
/// the buffer's declared element type decides the representation, and
/// values coerce on store exactly like the host read-back always did.
#[derive(Debug, Clone)]
pub(crate) enum BufData {
    F(Vec<f64>),
    I(Vec<i64>),
}

/// Memory image: one typed flat vector per buffer, plus an integer
/// register file for `read_irf`/`write_irf`.
#[derive(Debug, Clone, Default)]
pub struct Memory {
    pub(crate) bufs: Vec<BufData>,
    pub irf: [i64; 32],
}

impl Memory {
    /// Allocate every buffer declared by `func`, zero-initialized.
    pub fn for_func(func: &Func) -> Self {
        let mut mem = Memory::default();
        for decl in &func.buffers {
            mem.bufs.push(match decl.elem {
                DType::F32 => BufData::F(vec![0.0; decl.len]),
                DType::I32 => BufData::I(vec![0; decl.len]),
            });
        }
        mem
    }

    pub fn write_f32(&mut self, buf: BufferId, data: &[f32]) {
        match &mut self.bufs[buf.0 as usize] {
            BufData::F(v) => {
                for (slot, &x) in v.iter_mut().zip(data) {
                    *slot = x as f64;
                }
            }
            BufData::I(v) => {
                for (slot, &x) in v.iter_mut().zip(data) {
                    *slot = x as i64;
                }
            }
        }
    }

    pub fn write_i32(&mut self, buf: BufferId, data: &[i32]) {
        match &mut self.bufs[buf.0 as usize] {
            BufData::F(v) => {
                for (slot, &x) in v.iter_mut().zip(data) {
                    *slot = x as f64;
                }
            }
            BufData::I(v) => {
                for (slot, &x) in v.iter_mut().zip(data) {
                    *slot = x as i64;
                }
            }
        }
    }

    pub fn read_f32(&self, buf: BufferId) -> Vec<f32> {
        match &self.bufs[buf.0 as usize] {
            BufData::F(v) => v.iter().map(|&x| x as f32).collect(),
            BufData::I(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    pub fn read_i32(&self, buf: BufferId) -> Vec<i32> {
        match &self.bufs[buf.0 as usize] {
            BufData::F(v) => v.iter().map(|&x| x as i32).collect(),
            BufData::I(v) => v.iter().map(|&x| x as i32).collect(),
        }
    }

    /// Borrowed typed view of a float buffer (`None` for int buffers).
    pub fn f64s(&self, buf: BufferId) -> Option<&[f64]> {
        match &self.bufs[buf.0 as usize] {
            BufData::F(v) => Some(v),
            BufData::I(_) => None,
        }
    }

    /// Borrowed typed view of an int buffer (`None` for float buffers).
    pub fn i64s(&self, buf: BufferId) -> Option<&[i64]> {
        match &self.bufs[buf.0 as usize] {
            BufData::I(v) => Some(v),
            BufData::F(_) => None,
        }
    }

    fn get(&self, buf: BufferId, idx: i64, len: usize) -> Result<Val> {
        if idx < 0 || idx as usize >= len {
            return Err(Error::Ir(format!("index {idx} out of bounds (len {len})")));
        }
        Ok(match &self.bufs[buf.0 as usize] {
            BufData::F(v) => Val::F(v[idx as usize]),
            BufData::I(v) => Val::I(v[idx as usize]),
        })
    }

    fn set(&mut self, buf: BufferId, idx: i64, len: usize, val: Val) -> Result<()> {
        if idx < 0 || idx as usize >= len {
            return Err(Error::Ir(format!("index {idx} out of bounds (len {len})")));
        }
        match &mut self.bufs[buf.0 as usize] {
            BufData::F(v) => {
                v[idx as usize] = match val {
                    Val::F(x) => x,
                    Val::I(x) => x as f64,
                }
            }
            BufData::I(v) => {
                v[idx as usize] = match val {
                    Val::I(x) => x,
                    Val::F(x) => x as i64,
                }
            }
        }
        Ok(())
    }

    /// Bulk element copy (element offsets, not bytes). Bounds must have
    /// been validated by the caller ([`checked_copy`]). Same-buffer
    /// copies keep the historical forward element-by-element semantics;
    /// distinct same-typed buffers are a straight slice copy.
    pub(crate) fn bulk_copy(&mut self, dst: BufferId, d0: usize, src: BufferId, s0: usize, n: usize) {
        if n == 0 {
            return;
        }
        let (di, si) = (dst.0 as usize, src.0 as usize);
        if di == si {
            match &mut self.bufs[di] {
                BufData::F(v) => {
                    for i in 0..n {
                        v[d0 + i] = v[s0 + i];
                    }
                }
                BufData::I(v) => {
                    for i in 0..n {
                        v[d0 + i] = v[s0 + i];
                    }
                }
            }
            return;
        }
        let (dbuf, sbuf) = if di < si {
            let (lo, hi) = self.bufs.split_at_mut(si);
            (&mut lo[di], &hi[0])
        } else {
            let (lo, hi) = self.bufs.split_at_mut(di);
            (&mut hi[0], &lo[si])
        };
        match (dbuf, sbuf) {
            (BufData::F(d), BufData::F(s)) => d[d0..d0 + n].copy_from_slice(&s[s0..s0 + n]),
            (BufData::I(d), BufData::I(s)) => d[d0..d0 + n].copy_from_slice(&s[s0..s0 + n]),
            (BufData::F(d), BufData::I(s)) => {
                for i in 0..n {
                    d[d0 + i] = s[s0 + i] as f64;
                }
            }
            (BufData::I(d), BufData::F(s)) => {
                for i in 0..n {
                    d[d0 + i] = s[s0 + i] as i64;
                }
            }
        }
    }
}

/// Execution statistics (also consumed by the Rocket-like cost model).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecStats {
    pub arith_ops: u64,
    pub loads: u64,
    pub stores: u64,
    pub loop_iterations: u64,
    pub branches: u64,
    pub transfers: u64,
    pub transfer_bytes: u64,
    pub intrinsic_calls: u64,
    /// Issue-stream DMA makespan: the maximum simulated completion
    /// cycle across every temporal-level `copy_issue` executed so far
    /// (not the most recent one — a later issue on a fast channel can
    /// complete before an earlier burst), priced by the incremental
    /// §4.1 DMA clock
    /// ([`crate::interface::dmasim::IssueClock`]). By default the clock
    /// binds the §6.1 Rocket interface pair (Aquas-IR carries only
    /// interface *ids*); [`run_with_itfcs`] binds the real
    /// `InterfaceSet` the program was synthesized against (e.g. the
    /// §6.3 128-bit wide bus) so the billing matches the hardware.
    /// Interface ids beyond the bound set are a hard
    /// [`Error::Interface`](crate::error::Error) — the old silent clamp
    /// priced the wrong channel. Timing-only: functional results are
    /// unaffected, and both IR engines charge bit-identical values. 0
    /// when the program issues no DMA transactions.
    pub dma_cycles: u64,
}

/// One memory access in a trace (consumed by the cache model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemAccess {
    pub buf: BufferId,
    /// Element index.
    pub index: i64,
    pub is_store: bool,
}

/// Deterministic execution fuel, shared by the tree-walker and the
/// bytecode VM ([`crate::ir::vm`]).
///
/// Fuel is charged **per billable event**, at exactly the sites where
/// [`ExecStats`] counters increment (one unit per arith op / load /
/// store / transfer / branch / loop iteration / intrinsic call, `e`
/// units for `powi(e)`), plus one unit each for `read_irf`/`write_irf`
/// and `copy_wait`, plus the simulated §4.1 DMA beat count on every
/// `copy_issue` (via [`IssueClock::txn_beats`]). Consts, casts
/// (`to_float`/`to_int`), yields and returns are free — the VM executes
/// them differently (consts preload, stores emit coercion casts), so
/// billing them would break cross-engine determinism.
///
/// Charging is **pre-execution**: when the next event cannot be
/// afforded, [`Error::Fuel`] is raised *before* the op runs — no memory
/// mutation, no stats increment. Both engines therefore stop at the
/// identical event with identical partial stats and identical memory
/// images. An unlimited budget ([`Fuel::unlimited`]) never trips the
/// check, making the fueled path bitwise identical to [`run`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fuel {
    budget: u64,
    spent: u64,
    events: u64,
}

impl Fuel {
    /// A budget of `budget` fuel units.
    pub fn new(budget: u64) -> Self {
        Self { budget, spent: 0, events: 0 }
    }

    /// A budget that never exhausts (`u64::MAX` units).
    pub fn unlimited() -> Self {
        Self::new(u64::MAX)
    }

    /// Fuel units charged so far.
    pub fn spent(&self) -> u64 {
        self.spent
    }

    /// Billable events charged so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Remaining budget.
    pub fn remaining(&self) -> u64 {
        self.budget - self.spent
    }

    /// Charge one billable event of `cost` units; zero-cost events are
    /// free (not billed, not counted). Errors with [`Error::Fuel`] when
    /// the event cannot be afforded, charging nothing.
    #[inline]
    pub fn charge(&mut self, cost: u64) -> Result<()> {
        if cost == 0 {
            return Ok(());
        }
        if cost > self.budget - self.spent {
            return Err(Error::Fuel { spent: self.spent, at_op: self.events });
        }
        self.spent += cost;
        self.events += 1;
        Ok(())
    }
}

/// Interpret `func` with scalar `args` against `mem`.
/// Returns the function's `return` values.
pub fn run(func: &Func, args: &[Val], mem: &mut Memory) -> Result<Vec<Val>> {
    let mut stats = ExecStats::default();
    run_with_stats(func, args, mem, &mut stats)
}

/// Interpret and collect [`ExecStats`].
pub fn run_with_stats(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
) -> Result<Vec<Val>> {
    run_traced(func, args, mem, stats, &mut None)
}

/// Interpret, collect [`ExecStats`], and (optionally) a full memory trace.
pub fn run_traced(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
    trace: &mut Option<Vec<MemAccess>>,
) -> Result<Vec<Val>> {
    let mut fuel = Fuel::unlimited();
    run_traced_from(func, args, mem, stats, trace, None, &mut fuel)
}

/// Interpret with DMA issue ops priced against a *specific*
/// [`InterfaceSet`] — the set the program was synthesized for — instead
/// of the default §6.1 Rocket pair. Functional results are bit-identical
/// to [`run`]; only [`ExecStats::dma_cycles`] (and the hard-error range
/// check on interface ids) observe the bound set.
pub fn run_with_itfcs(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
    itfcs: &InterfaceSet,
) -> Result<Vec<Val>> {
    let mut fuel = Fuel::unlimited();
    run_traced_from(
        func,
        args,
        mem,
        stats,
        &mut None,
        Some(IssueClock::new(itfcs.clone())),
        &mut fuel,
    )
}

/// Interpret under a [`Fuel`] budget: every billable event is charged
/// before it executes, and exhaustion aborts with [`Error::Fuel`] at a
/// deterministic point — the same point, partial [`ExecStats`] and
/// memory image the bytecode VM's
/// [`run_fueled`](crate::ir::vm::CompiledFunc::run_fueled) produces.
/// With [`Fuel::unlimited`] this is bitwise identical to
/// [`run_with_stats`]. The caller's `fuel` records the spend either way.
pub fn run_fueled(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
    fuel: &mut Fuel,
) -> Result<Vec<Val>> {
    run_traced_from(func, args, mem, stats, &mut None, None, fuel)
}

/// Shared interpreter entry: `dma0` pre-binds the issue clock (`None`
/// lazily builds the Rocket-default clock on first `copy_issue`).
#[allow(clippy::too_many_arguments)]
fn run_traced_from(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
    trace: &mut Option<Vec<MemAccess>>,
    dma0: Option<IssueClock>,
    fuel: &mut Fuel,
) -> Result<Vec<Val>> {
    if args.len() != func.params.len() {
        return Err(Error::Ir(format!(
            "expected {} args, got {}",
            func.params.len(),
            args.len()
        )));
    }
    let mut env: HashMap<Value, Val> = HashMap::new();
    for (&p, &a) in func.params.iter().zip(args) {
        env.insert(p, a);
    }
    // Temporal level: issued-but-not-awaited transactions, plus the
    // incremental DMA clock that prices them (lazily built — programs
    // without issue ops never pay for it — unless a caller bound one).
    let mut pending: HashMap<u32, PendingCopy> = HashMap::new();
    let mut dma: Option<IssueClock> = dma0;
    let out = exec_region(
        func, &func.entry, &mut env, mem, stats, &mut pending, &mut dma, trace, fuel,
    )?;
    Ok(out.unwrap_or_default())
}

#[derive(Debug, Clone)]
struct PendingCopy {
    dst: BufferId,
    src: BufferId,
    dst_off: i64,
    src_off: i64,
    size: usize,
}

/// Execute a region; `Some(values)` when a Yield/Return fired.
#[allow(clippy::too_many_arguments)]
fn exec_region(
    func: &Func,
    region: &Region,
    env: &mut HashMap<Value, Val>,
    mem: &mut Memory,
    stats: &mut ExecStats,
    pending: &mut HashMap<u32, PendingCopy>,
    dma: &mut Option<IssueClock>,
    trace: &mut Option<Vec<MemAccess>>,
    fuel: &mut Fuel,
) -> Result<Option<Vec<Val>>> {
    for &opref in &region.ops {
        let op = func.op(opref);
        if let Some(vals) = exec_op(func, op, env, mem, stats, pending, dma, trace, fuel)? {
            return Ok(Some(vals));
        }
    }
    Ok(None)
}

#[allow(clippy::too_many_arguments)]
fn exec_op(
    func: &Func,
    op: &Op,
    env: &mut HashMap<Value, Val>,
    mem: &mut Memory,
    stats: &mut ExecStats,
    pending: &mut HashMap<u32, PendingCopy>,
    dma: &mut Option<IssueClock>,
    trace: &mut Option<Vec<MemAccess>>,
    fuel: &mut Fuel,
) -> Result<Option<Vec<Val>>> {
    let get = |env: &HashMap<Value, Val>, v: Value| -> Result<Val> {
        env.get(&v).copied().ok_or_else(|| Error::Ir(format!("undefined value {v}")))
    };
    macro_rules! set1 {
        ($val:expr) => {{
            env.insert(op.results[0], $val);
        }};
    }

    match &op.kind {
        OpKind::ConstI(c) => set1!(Val::I(*c)),
        OpKind::ConstF(c) => set1!(Val::F(*c)),
        OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div | OpKind::Min | OpKind::Max => {
            fuel.charge(1)?;
            stats.arith_ops += 1;
            let a = get(env, op.operands[0])?;
            let b = get(env, op.operands[1])?;
            let r = match (a, b) {
                (Val::I(x), Val::I(y)) => Val::I(int_bin(&op.kind, x, y)?),
                (Val::F(x), Val::F(y)) => Val::F(float_bin(&op.kind, x, y)),
                _ => return Err(Error::Ir(format!("{}: mixed types", op.kind.mnemonic()))),
            };
            set1!(r)
        }
        OpKind::Rem | OpKind::Shl | OpKind::Shr | OpKind::And | OpKind::Or | OpKind::Xor => {
            fuel.charge(1)?;
            stats.arith_ops += 1;
            let x = get(env, op.operands[0])?.as_i()?;
            let y = get(env, op.operands[1])?.as_i()?;
            let r = match op.kind {
                OpKind::Rem => {
                    if y == 0 {
                        return Err(Error::Ir("remainder by zero".into()));
                    }
                    // Wrapping: `i64::MIN % -1` must not overflow-panic.
                    x.wrapping_rem(y)
                }
                OpKind::Shl => x.wrapping_shl(y as u32),
                OpKind::Shr => x.wrapping_shr(y as u32),
                OpKind::And => x & y,
                OpKind::Or => x | y,
                OpKind::Xor => x ^ y,
                _ => unreachable!(),
            };
            set1!(Val::I(r))
        }
        OpKind::Neg => {
            fuel.charge(1)?;
            stats.arith_ops += 1;
            let r = match get(env, op.operands[0])? {
                // Wrapping, like every other int op: `-i64::MIN` must not
                // panic under debug overflow checks (the mid-end may
                // speculate `neg` — `analysis::can_trap` calls it safe).
                Val::I(x) => Val::I(x.wrapping_neg()),
                Val::F(x) => Val::F(-x),
            };
            set1!(r)
        }
        OpKind::Sqrt => {
            fuel.charge(1)?;
            stats.arith_ops += 1;
            set1!(Val::F(get(env, op.operands[0])?.as_f()?.sqrt()))
        }
        OpKind::Exp => {
            fuel.charge(1)?;
            stats.arith_ops += 1;
            set1!(Val::F(get(env, op.operands[0])?.as_f()?.exp()))
        }
        OpKind::Powi(e) => {
            fuel.charge(*e as u64)?;
            stats.arith_ops += *e as u64;
            set1!(Val::F(get(env, op.operands[0])?.as_f()?.powi(*e as i32)))
        }
        OpKind::ToFloat => set1!(Val::F(get(env, op.operands[0])?.as_i()? as f64)),
        OpKind::ToInt => set1!(Val::I(get(env, op.operands[0])?.as_f()? as i64)),
        OpKind::Cmp(pred) => {
            fuel.charge(1)?;
            stats.arith_ops += 1;
            let a = get(env, op.operands[0])?;
            let b = get(env, op.operands[1])?;
            let ord = match (a, b) {
                (Val::I(x), Val::I(y)) => x.partial_cmp(&y),
                (Val::F(x), Val::F(y)) => x.partial_cmp(&y),
                _ => return Err(Error::Ir("cmp: mixed types".into())),
            }
            .ok_or_else(|| Error::Ir("cmp: unordered (NaN)".into()))?;
            let r = match pred {
                CmpPred::Eq => ord.is_eq(),
                CmpPred::Ne => ord.is_ne(),
                CmpPred::Lt => ord.is_lt(),
                CmpPred::Le => ord.is_le(),
                CmpPred::Gt => ord.is_gt(),
                CmpPred::Ge => ord.is_ge(),
            };
            set1!(Val::I(r as i64))
        }
        OpKind::Select => {
            fuel.charge(1)?;
            stats.arith_ops += 1;
            let c = get(env, op.operands[0])?.as_i()?;
            let r = if c != 0 { get(env, op.operands[1])? } else { get(env, op.operands[2])? };
            set1!(r)
        }
        OpKind::Load(b) | OpKind::Fetch(b) | OpKind::ReadSmem(b) => {
            fuel.charge(1)?;
            stats.loads += 1;
            let idx = get(env, op.operands[0])?.as_i()?;
            if let Some(t) = trace.as_mut() {
                t.push(MemAccess { buf: *b, index: idx, is_store: false });
            }
            set1!(mem.get(*b, idx, func.buffer(*b).len)?)
        }
        OpKind::LoadItfc { buf, .. } => {
            fuel.charge(1)?;
            stats.loads += 1;
            let idx = get(env, op.operands[0])?.as_i()?;
            if let Some(t) = trace.as_mut() {
                t.push(MemAccess { buf: *buf, index: idx, is_store: false });
            }
            set1!(mem.get(*buf, idx, func.buffer(*buf).len)?)
        }
        OpKind::Store(b) | OpKind::WriteSmem(b) => {
            fuel.charge(1)?;
            stats.stores += 1;
            let idx = get(env, op.operands[0])?.as_i()?;
            if let Some(t) = trace.as_mut() {
                t.push(MemAccess { buf: *b, index: idx, is_store: true });
            }
            let v = get(env, op.operands[1])?;
            mem.set(*b, idx, func.buffer(*b).len, v)?;
        }
        OpKind::StoreItfc { buf, .. } => {
            fuel.charge(1)?;
            stats.stores += 1;
            let idx = get(env, op.operands[0])?.as_i()?;
            if let Some(t) = trace.as_mut() {
                t.push(MemAccess { buf: *buf, index: idx, is_store: true });
            }
            let v = get(env, op.operands[1])?;
            mem.set(*buf, idx, func.buffer(*buf).len, v)?;
        }
        OpKind::ReadIrf(r) => {
            fuel.charge(1)?;
            set1!(Val::I(mem.irf[*r as usize]))
        }
        OpKind::WriteIrf(r) => {
            fuel.charge(1)?;
            mem.irf[*r as usize] = get(env, op.operands[0])?.as_i()?;
        }
        OpKind::Transfer { dst, src, size } | OpKind::Copy { dst, src, size, .. } => {
            fuel.charge(1)?;
            stats.transfers += 1;
            stats.transfer_bytes += *size as u64;
            let dst_off = get(env, op.operands[0])?.as_i()?;
            let src_off = get(env, op.operands[1])?.as_i()?;
            checked_copy(
                mem,
                *dst,
                dst_off,
                *src,
                src_off,
                *size,
                func.buffer(*dst).len,
                func.buffer(*src).len,
            )?;
        }
        OpKind::CopyIssue { dst, src, size, tag, itfc, kind, .. } => {
            // Timing only: charge the simulated §4.1 completion cycle of
            // this transaction; data still moves at the matching wait.
            // Fuel prices the issue itself plus its bus occupancy (beats),
            // so a fuel budget bounds simulated DMA work, not just op count.
            let clk = dma.get_or_insert_with(IssueClock::rocket_default);
            fuel.charge(1 + clk.txn_beats(*itfc, *size))?;
            stats.transfers += 1;
            stats.transfer_bytes += *size as u64;
            let done = clk.issue(*itfc, *kind, *size)?;
            stats.dma_cycles = stats.dma_cycles.max(done);
            let dst_off = get(env, op.operands[0])?.as_i()?;
            let src_off = get(env, op.operands[1])?.as_i()?;
            pending.insert(
                *tag,
                PendingCopy { dst: *dst, src: *src, dst_off, src_off, size: *size },
            );
        }
        OpKind::CopyWait { tag } => {
            fuel.charge(1)?;
            let p = pending
                .remove(tag)
                .ok_or_else(|| Error::Ir(format!("copy_wait: unknown tag {tag}")))?;
            checked_copy(
                mem,
                p.dst,
                p.dst_off,
                p.src,
                p.src_off,
                p.size,
                func.buffer(p.dst).len,
                func.buffer(p.src).len,
            )?;
        }
        OpKind::For => {
            let lb = get(env, op.operands[0])?.as_i()?;
            let ub = get(env, op.operands[1])?.as_i()?;
            let step = get(env, op.operands[2])?.as_i()?;
            if step <= 0 {
                return Err(Error::Ir(format!("for: non-positive step {step}")));
            }
            let region = &op.regions[0];
            let iv = region.params[0];
            let carried: Vec<Value> = region.params[1..].to_vec();
            let mut vals: Vec<Val> = op.operands[3..]
                .iter()
                .map(|&v| get(env, v))
                .collect::<Result<_>>()?;
            let mut i = lb;
            while i < ub {
                fuel.charge(1)?;
                stats.loop_iterations += 1;
                stats.branches += 1;
                env.insert(iv, Val::I(i));
                for (&cv, &val) in carried.iter().zip(&vals) {
                    env.insert(cv, val);
                }
                match exec_region(func, region, env, mem, stats, pending, dma, trace, fuel)? {
                    Some(y) => vals = y,
                    None => return Err(Error::Ir("for body missing yield".into())),
                }
                i += step;
            }
            for (&res, &val) in op.results.iter().zip(&vals) {
                env.insert(res, val);
            }
        }
        OpKind::If => {
            fuel.charge(1)?;
            stats.branches += 1;
            let c = get(env, op.operands[0])?.as_i()?;
            let region = if c != 0 { &op.regions[0] } else { &op.regions[1] };
            match exec_region(func, region, env, mem, stats, pending, dma, trace, fuel)? {
                Some(vals) => {
                    for (&res, &val) in op.results.iter().zip(&vals) {
                        env.insert(res, val);
                    }
                }
                None => return Err(Error::Ir("if arm missing yield".into())),
            }
        }
        OpKind::Yield | OpKind::Return => {
            let vals: Vec<Val> =
                op.operands.iter().map(|&v| get(env, v)).collect::<Result<_>>()?;
            return Ok(Some(vals));
        }
        OpKind::Intrinsic(name) => {
            fuel.charge(1)?;
            stats.intrinsic_calls += 1;
            return Err(Error::Ir(format!(
                "intrinsic `{name}` reached the reference interpreter; lower it or \
                 execute through the ISAX engine"
            )));
        }
    }
    Ok(None)
}

/// Validate + perform one bulk copy. Offsets/sizes are in bytes; elements
/// are 4 bytes. Shared verbatim by the tree-walker and the bytecode VM so
/// transfer semantics (including error strings) cannot diverge.
#[allow(clippy::too_many_arguments)]
pub(crate) fn checked_copy(
    mem: &mut Memory,
    dst: BufferId,
    dst_off: i64,
    src: BufferId,
    src_off: i64,
    size: usize,
    dlen: usize,
    slen: usize,
) -> Result<()> {
    if size % 4 != 0 || dst_off % 4 != 0 || src_off % 4 != 0 {
        return Err(Error::Ir("transfer offsets/size must be 4B-aligned".into()));
    }
    let n = size / 4;
    let d0 = (dst_off / 4) as usize;
    let s0 = (src_off / 4) as usize;
    // Overflow-safe spelling of `d0 + n > dlen || s0 + n > slen` (negative
    // byte offsets cast to huge usizes).
    if d0 > dlen || n > dlen - d0 || s0 > slen || n > slen - s0 {
        return Err(Error::Ir(format!(
            "transfer out of bounds: dst {d0}+{n}>{dlen} or src {s0}+{n}>{slen}"
        )));
    }
    mem.bulk_copy(dst, d0, src, s0, n);
    Ok(())
}

fn int_bin(kind: &OpKind, x: i64, y: i64) -> Result<i64> {
    Ok(match kind {
        OpKind::Add => x.wrapping_add(y),
        OpKind::Sub => x.wrapping_sub(y),
        OpKind::Mul => x.wrapping_mul(y),
        OpKind::Div => {
            if y == 0 {
                return Err(Error::Ir("division by zero".into()));
            }
            // Wrapping: `i64::MIN / -1` must not overflow-panic on
            // hostile input (it stays i64::MIN, same as the VM).
            x.wrapping_div(y)
        }
        OpKind::Min => x.min(y),
        OpKind::Max => x.max(y),
        _ => unreachable!(),
    })
}

fn float_bin(kind: &OpKind, x: f64, y: f64) -> f64 {
    match kind {
        OpKind::Add => x + y,
        OpKind::Sub => x - y,
        OpKind::Mul => x * y,
        OpKind::Div => x / y,
        OpKind::Min => x.min(y),
        OpKind::Max => x.max(y),
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::ir::types::Type;

    #[test]
    fn sum_loop() {
        let mut b = FuncBuilder::new("sum");
        let buf = b.global("x", DType::I32, 8, CacheHint::Unknown);
        let zero = b.const_i(0);
        let lb = b.const_i(0);
        let ub = b.const_i(8);
        let one = b.const_i(1);
        let sums = b.for_loop(lb, ub, one, &[zero], |b, iv, carried| {
            let x = b.load(buf, iv);
            vec![b.add(carried[0], x)]
        });
        let f = b.finish(&sums);
        let mut mem = Memory::for_func(&f);
        mem.write_i32(crate::ir::func::BufferId(0), &[1, 2, 3, 4, 5, 6, 7, 8]);
        let out = run(&f, &[], &mut mem).unwrap();
        assert_eq!(out, vec![Val::I(36)]);
    }

    #[test]
    fn transfer_moves_bytes() {
        let mut b = FuncBuilder::new("t");
        let g = b.global("g", DType::F32, 16, CacheHint::Cold);
        let s = b.scratchpad("s", DType::F32, 16, 1);
        let zero = b.const_i(0);
        b.transfer(s, zero, g, zero, 16 * 4);
        let f = b.finish(&[]);
        let mut mem = Memory::for_func(&f);
        let data: Vec<f32> = (0..16).map(|i| i as f32).collect();
        mem.write_f32(crate::ir::func::BufferId(0), &data);
        run(&f, &[], &mut mem).unwrap();
        assert_eq!(mem.read_f32(crate::ir::func::BufferId(1)), data);
    }

    #[test]
    fn issue_wait_pairs_complete_at_wait() {
        use crate::interface::model::InterfaceId;
        use crate::interface::TransactionKind;
        let mut b = FuncBuilder::new("t");
        let g = b.global("g", DType::I32, 4, CacheHint::Unknown);
        let s = b.scratchpad("s", DType::I32, 4, 1);
        let zero = b.const_i(0);
        // hand-emit issue/wait
        let mut f = {
            b.transfer(s, zero, g, zero, 0); // placeholder replaced below
            b.finish(&[])
        };
        // Replace the placeholder transfer with issue+wait ops.
        let issue = f.add_op(Op::new(
            OpKind::CopyIssue {
                itfc: InterfaceId(0),
                dst: crate::ir::func::BufferId(1),
                src: crate::ir::func::BufferId(0),
                size: 16,
                kind: TransactionKind::Load,
                tag: 7,
                after: vec![],
            },
            vec![Value(0), Value(0)],
            vec![],
        ));
        let wait = f.add_op(Op::new(OpKind::CopyWait { tag: 7 }, vec![], vec![]));
        let ret = f.entry.ops.pop().unwrap(); // return
        f.entry.ops.pop(); // placeholder transfer
        f.entry.ops.push(issue);
        f.entry.ops.push(wait);
        f.entry.ops.push(ret);

        let mut mem = Memory::for_func(&f);
        mem.write_i32(crate::ir::func::BufferId(0), &[9, 8, 7, 6]);
        run(&f, &[], &mut mem).unwrap();
        assert_eq!(mem.read_i32(crate::ir::func::BufferId(1)), vec![9, 8, 7, 6]);

        // Binding a real interface set: same data movement, and the DMA
        // billing follows the bound geometry instead of the default pair.
        let set = InterfaceSet::rocket_default();
        let mut mem2 = Memory::for_func(&f);
        mem2.write_i32(crate::ir::func::BufferId(0), &[9, 8, 7, 6]);
        let mut stats = ExecStats::default();
        run_with_itfcs(&f, &[], &mut mem2, &mut stats, &set).unwrap();
        assert_eq!(mem2.read_i32(crate::ir::func::BufferId(1)), vec![9, 8, 7, 6]);
        assert!(stats.dma_cycles > 0);

        // An id beyond the bound set is a hard error, not a clamp: bind
        // an empty set so the op's InterfaceId(0) has no channel.
        let empty = InterfaceSet::new(vec![]);
        let mut mem3 = Memory::for_func(&f);
        mem3.write_i32(crate::ir::func::BufferId(0), &[9, 8, 7, 6]);
        let mut stats3 = ExecStats::default();
        let err = run_with_itfcs(&f, &[], &mut mem3, &mut stats3, &empty).unwrap_err();
        assert!(err.to_string().contains("unknown interface"), "{err}");
    }

    #[test]
    fn if_else_selects_arm() {
        let mut b = FuncBuilder::new("t");
        let p = b.param(Type::Int);
        let zero = b.const_i(0);
        let c = b.cmp(CmpPred::Gt, p, zero);
        let r = b.if_else(c, |b| vec![b.const_i(10)], |b| vec![b.const_i(20)]);
        let f = b.finish(&r);
        let mut mem = Memory::for_func(&f);
        assert_eq!(run(&f, &[Val::I(5)], &mut mem).unwrap(), vec![Val::I(10)]);
        assert_eq!(run(&f, &[Val::I(-5)], &mut mem).unwrap(), vec![Val::I(20)]);
    }

    #[test]
    fn stats_count_work() {
        let mut b = FuncBuilder::new("t");
        let buf = b.global("x", DType::I32, 4, CacheHint::Unknown);
        b.for_range(0, 4, 1, |b, iv| {
            let v = b.load(buf, iv);
            let one = b.const_i(1);
            let w = b.add(v, one);
            b.store(buf, iv, w);
        });
        let f = b.finish(&[]);
        let mut mem = Memory::for_func(&f);
        let mut stats = ExecStats::default();
        run_with_stats(&f, &[], &mut mem, &mut stats).unwrap();
        assert_eq!(stats.loads, 4);
        assert_eq!(stats.stores, 4);
        assert_eq!(stats.loop_iterations, 4);
        assert_eq!(stats.arith_ops, 4);
    }

    #[test]
    fn out_of_bounds_rejected() {
        let mut b = FuncBuilder::new("t");
        let buf = b.global("x", DType::I32, 2, CacheHint::Unknown);
        let idx = b.const_i(5);
        let v = b.load(buf, idx);
        let f = b.finish(&[v]);
        let mut mem = Memory::for_func(&f);
        assert!(run(&f, &[], &mut mem).is_err());
    }

    #[test]
    fn exp_evaluates_and_counts() {
        let mut b = FuncBuilder::new("e");
        let x = b.const_f(1.5);
        let e = b.exp(x);
        let f = b.finish(&[e]);
        let mut mem = Memory::for_func(&f);
        let mut stats = ExecStats::default();
        let out = run_with_stats(&f, &[], &mut mem, &mut stats).unwrap();
        assert_eq!(out, vec![Val::F(1.5f64.exp())]);
        assert_eq!(stats.arith_ops, 1);
    }

    #[test]
    fn typed_views_expose_arena() {
        let mut b = FuncBuilder::new("v");
        let g = b.global("g", DType::F32, 4, CacheHint::Unknown);
        let i = b.global("i", DType::I32, 4, CacheHint::Unknown);
        let f = b.finish(&[]);
        let mut mem = Memory::for_func(&f);
        mem.write_f32(g, &[1.0, 2.0, 3.0, 4.0]);
        mem.write_i32(i, &[5, 6, 7, 8]);
        assert_eq!(mem.f64s(g).unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(mem.i64s(i).unwrap(), &[5, 6, 7, 8]);
        assert!(mem.f64s(i).is_none());
        assert!(mem.i64s(g).is_none());
        assert_eq!(mem.read_f32(g), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(mem.read_i32(i), vec![5, 6, 7, 8]);
    }
}
