//! Reference interpreter for Aquas-IR.
//!
//! Executes a function at *any* level (functional transfers, architectural
//! copies, temporal issue/wait pairs all move the same bytes) against a
//! memory image. This gives the semantic ground truth used to prove that
//! synthesis transformations (§4.3) and compiler rewrites (§5.3) preserve
//! behaviour, and to check the ISAX datapaths against the AOT Pallas
//! artifacts (see `rust/tests/`).

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::ir::func::{BufferId, Func, Region, Value};
use crate::ir::ops::{CmpPred, Op, OpKind};
use crate::runtime::DType;

/// A runtime scalar.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Val {
    I(i64),
    F(f64),
}

impl Val {
    pub fn as_i(&self) -> Result<i64> {
        match self {
            Val::I(v) => Ok(*v),
            Val::F(v) => Err(Error::Ir(format!("expected int, got float {v}"))),
        }
    }

    pub fn as_f(&self) -> Result<f64> {
        match self {
            Val::F(v) => Ok(*v),
            Val::I(v) => Err(Error::Ir(format!("expected float, got int {v}"))),
        }
    }
}

/// Memory image: one typed vector per buffer, plus an integer register file
/// for `read_irf`/`write_irf`.
#[derive(Debug, Clone, Default)]
pub struct Memory {
    bufs: HashMap<BufferId, Vec<Val>>,
    pub irf: [i64; 32],
}

impl Memory {
    /// Allocate every buffer declared by `func`, zero-initialized.
    pub fn for_func(func: &Func) -> Self {
        let mut mem = Memory::default();
        for (i, decl) in func.buffers.iter().enumerate() {
            let zero = match decl.elem {
                DType::F32 => Val::F(0.0),
                DType::I32 => Val::I(0),
            };
            mem.bufs.insert(BufferId(i as u32), vec![zero; decl.len]);
        }
        mem
    }

    pub fn write_f32(&mut self, buf: BufferId, data: &[f32]) {
        let v = self.bufs.get_mut(&buf).expect("unknown buffer");
        for (slot, &x) in v.iter_mut().zip(data) {
            *slot = Val::F(x as f64);
        }
    }

    pub fn write_i32(&mut self, buf: BufferId, data: &[i32]) {
        let v = self.bufs.get_mut(&buf).expect("unknown buffer");
        for (slot, &x) in v.iter_mut().zip(data) {
            *slot = Val::I(x as i64);
        }
    }

    pub fn read_f32(&self, buf: BufferId) -> Vec<f32> {
        self.bufs[&buf].iter().map(|v| match v {
            Val::F(x) => *x as f32,
            Val::I(x) => *x as f32,
        }).collect()
    }

    pub fn read_i32(&self, buf: BufferId) -> Vec<i32> {
        self.bufs[&buf].iter().map(|v| match v {
            Val::I(x) => *x as i32,
            Val::F(x) => *x as i32,
        }).collect()
    }

    fn get(&self, buf: BufferId, idx: i64, len: usize) -> Result<Val> {
        if idx < 0 || idx as usize >= len {
            return Err(Error::Ir(format!("index {idx} out of bounds (len {len})")));
        }
        Ok(self.bufs[&buf][idx as usize])
    }

    fn set(&mut self, buf: BufferId, idx: i64, len: usize, val: Val) -> Result<()> {
        if idx < 0 || idx as usize >= len {
            return Err(Error::Ir(format!("index {idx} out of bounds (len {len})")));
        }
        self.bufs.get_mut(&buf).unwrap()[idx as usize] = val;
        Ok(())
    }
}

/// Execution statistics (also consumed by the Rocket-like cost model).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecStats {
    pub arith_ops: u64,
    pub loads: u64,
    pub stores: u64,
    pub loop_iterations: u64,
    pub branches: u64,
    pub transfers: u64,
    pub transfer_bytes: u64,
    pub intrinsic_calls: u64,
}

/// One memory access in a trace (consumed by the cache model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemAccess {
    pub buf: BufferId,
    /// Element index.
    pub index: i64,
    pub is_store: bool,
}

/// Interpret `func` with scalar `args` against `mem`.
/// Returns the function's `return` values.
pub fn run(func: &Func, args: &[Val], mem: &mut Memory) -> Result<Vec<Val>> {
    let mut stats = ExecStats::default();
    run_with_stats(func, args, mem, &mut stats)
}

/// Interpret and collect [`ExecStats`].
pub fn run_with_stats(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
) -> Result<Vec<Val>> {
    run_traced(func, args, mem, stats, &mut None)
}

/// Interpret, collect [`ExecStats`], and (optionally) a full memory trace.
pub fn run_traced(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
    trace: &mut Option<Vec<MemAccess>>,
) -> Result<Vec<Val>> {
    if args.len() != func.params.len() {
        return Err(Error::Ir(format!(
            "expected {} args, got {}",
            func.params.len(),
            args.len()
        )));
    }
    let mut env: HashMap<Value, Val> = HashMap::new();
    for (&p, &a) in func.params.iter().zip(args) {
        env.insert(p, a);
    }
    // Temporal level: issued-but-not-awaited transactions.
    let mut pending: HashMap<u32, PendingCopy> = HashMap::new();
    let out = exec_region(func, &func.entry, &mut env, mem, stats, &mut pending, trace)?;
    Ok(out.unwrap_or_default())
}

#[derive(Debug, Clone)]
struct PendingCopy {
    dst: BufferId,
    src: BufferId,
    dst_off: i64,
    src_off: i64,
    size: usize,
}

/// Execute a region; `Some(values)` when a Yield/Return fired.
fn exec_region(
    func: &Func,
    region: &Region,
    env: &mut HashMap<Value, Val>,
    mem: &mut Memory,
    stats: &mut ExecStats,
    pending: &mut HashMap<u32, PendingCopy>,
    trace: &mut Option<Vec<MemAccess>>,
) -> Result<Option<Vec<Val>>> {
    for &opref in &region.ops {
        let op = func.op(opref);
        if let Some(vals) = exec_op(func, op, env, mem, stats, pending, trace)? {
            return Ok(Some(vals));
        }
    }
    Ok(None)
}

fn exec_op(
    func: &Func,
    op: &Op,
    env: &mut HashMap<Value, Val>,
    mem: &mut Memory,
    stats: &mut ExecStats,
    pending: &mut HashMap<u32, PendingCopy>,
    trace: &mut Option<Vec<MemAccess>>,
) -> Result<Option<Vec<Val>>> {
    let get = |env: &HashMap<Value, Val>, v: Value| -> Result<Val> {
        env.get(&v).copied().ok_or_else(|| Error::Ir(format!("undefined value {v}")))
    };
    macro_rules! set1 {
        ($val:expr) => {{
            env.insert(op.results[0], $val);
        }};
    }

    match &op.kind {
        OpKind::ConstI(c) => set1!(Val::I(*c)),
        OpKind::ConstF(c) => set1!(Val::F(*c)),
        OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div | OpKind::Min | OpKind::Max => {
            stats.arith_ops += 1;
            let a = get(env, op.operands[0])?;
            let b = get(env, op.operands[1])?;
            let r = match (a, b) {
                (Val::I(x), Val::I(y)) => Val::I(int_bin(&op.kind, x, y)?),
                (Val::F(x), Val::F(y)) => Val::F(float_bin(&op.kind, x, y)),
                _ => return Err(Error::Ir(format!("{}: mixed types", op.kind.mnemonic()))),
            };
            set1!(r)
        }
        OpKind::Rem | OpKind::Shl | OpKind::Shr | OpKind::And | OpKind::Or | OpKind::Xor => {
            stats.arith_ops += 1;
            let x = get(env, op.operands[0])?.as_i()?;
            let y = get(env, op.operands[1])?.as_i()?;
            let r = match op.kind {
                OpKind::Rem => {
                    if y == 0 {
                        return Err(Error::Ir("remainder by zero".into()));
                    }
                    x % y
                }
                OpKind::Shl => x.wrapping_shl(y as u32),
                OpKind::Shr => x.wrapping_shr(y as u32),
                OpKind::And => x & y,
                OpKind::Or => x | y,
                OpKind::Xor => x ^ y,
                _ => unreachable!(),
            };
            set1!(Val::I(r))
        }
        OpKind::Neg => {
            stats.arith_ops += 1;
            let r = match get(env, op.operands[0])? {
                Val::I(x) => Val::I(-x),
                Val::F(x) => Val::F(-x),
            };
            set1!(r)
        }
        OpKind::Sqrt => {
            stats.arith_ops += 1;
            set1!(Val::F(get(env, op.operands[0])?.as_f()?.sqrt()))
        }
        OpKind::Powi(e) => {
            stats.arith_ops += *e as u64;
            set1!(Val::F(get(env, op.operands[0])?.as_f()?.powi(*e as i32)))
        }
        OpKind::ToFloat => set1!(Val::F(get(env, op.operands[0])?.as_i()? as f64)),
        OpKind::ToInt => set1!(Val::I(get(env, op.operands[0])?.as_f()? as i64)),
        OpKind::Cmp(pred) => {
            stats.arith_ops += 1;
            let a = get(env, op.operands[0])?;
            let b = get(env, op.operands[1])?;
            let ord = match (a, b) {
                (Val::I(x), Val::I(y)) => x.partial_cmp(&y),
                (Val::F(x), Val::F(y)) => x.partial_cmp(&y),
                _ => return Err(Error::Ir("cmp: mixed types".into())),
            }
            .ok_or_else(|| Error::Ir("cmp: unordered (NaN)".into()))?;
            let r = match pred {
                CmpPred::Eq => ord.is_eq(),
                CmpPred::Ne => ord.is_ne(),
                CmpPred::Lt => ord.is_lt(),
                CmpPred::Le => ord.is_le(),
                CmpPred::Gt => ord.is_gt(),
                CmpPred::Ge => ord.is_ge(),
            };
            set1!(Val::I(r as i64))
        }
        OpKind::Select => {
            stats.arith_ops += 1;
            let c = get(env, op.operands[0])?.as_i()?;
            let r = if c != 0 { get(env, op.operands[1])? } else { get(env, op.operands[2])? };
            set1!(r)
        }
        OpKind::Load(b) | OpKind::Fetch(b) | OpKind::ReadSmem(b) => {
            stats.loads += 1;
            let idx = get(env, op.operands[0])?.as_i()?;
            if let Some(t) = trace.as_mut() {
                t.push(MemAccess { buf: *b, index: idx, is_store: false });
            }
            set1!(mem.get(*b, idx, func.buffer(*b).len)?)
        }
        OpKind::LoadItfc { buf, .. } => {
            stats.loads += 1;
            let idx = get(env, op.operands[0])?.as_i()?;
            if let Some(t) = trace.as_mut() {
                t.push(MemAccess { buf: *buf, index: idx, is_store: false });
            }
            set1!(mem.get(*buf, idx, func.buffer(*buf).len)?)
        }
        OpKind::Store(b) | OpKind::WriteSmem(b) => {
            stats.stores += 1;
            let idx = get(env, op.operands[0])?.as_i()?;
            if let Some(t) = trace.as_mut() {
                t.push(MemAccess { buf: *b, index: idx, is_store: true });
            }
            let v = get(env, op.operands[1])?;
            mem.set(*b, idx, func.buffer(*b).len, v)?;
        }
        OpKind::StoreItfc { buf, .. } => {
            stats.stores += 1;
            let idx = get(env, op.operands[0])?.as_i()?;
            if let Some(t) = trace.as_mut() {
                t.push(MemAccess { buf: *buf, index: idx, is_store: true });
            }
            let v = get(env, op.operands[1])?;
            mem.set(*buf, idx, func.buffer(*buf).len, v)?;
        }
        OpKind::ReadIrf(r) => set1!(Val::I(mem.irf[*r as usize])),
        OpKind::WriteIrf(r) => {
            mem.irf[*r as usize] = get(env, op.operands[0])?.as_i()?;
        }
        OpKind::Transfer { dst, src, size } | OpKind::Copy { dst, src, size, .. } => {
            stats.transfers += 1;
            stats.transfer_bytes += *size as u64;
            let dst_off = get(env, op.operands[0])?.as_i()?;
            let src_off = get(env, op.operands[1])?.as_i()?;
            do_copy(func, mem, *dst, dst_off, *src, src_off, *size)?;
        }
        OpKind::CopyIssue { dst, src, size, tag, .. } => {
            stats.transfers += 1;
            stats.transfer_bytes += *size as u64;
            let dst_off = get(env, op.operands[0])?.as_i()?;
            let src_off = get(env, op.operands[1])?.as_i()?;
            pending.insert(
                *tag,
                PendingCopy { dst: *dst, src: *src, dst_off, src_off, size: *size },
            );
        }
        OpKind::CopyWait { tag } => {
            let p = pending
                .remove(tag)
                .ok_or_else(|| Error::Ir(format!("copy_wait: unknown tag {tag}")))?;
            do_copy(func, mem, p.dst, p.dst_off, p.src, p.src_off, p.size)?;
        }
        OpKind::For => {
            let lb = get(env, op.operands[0])?.as_i()?;
            let ub = get(env, op.operands[1])?.as_i()?;
            let step = get(env, op.operands[2])?.as_i()?;
            if step <= 0 {
                return Err(Error::Ir(format!("for: non-positive step {step}")));
            }
            let region = &op.regions[0];
            let iv = region.params[0];
            let carried: Vec<Value> = region.params[1..].to_vec();
            let mut vals: Vec<Val> = op.operands[3..]
                .iter()
                .map(|&v| get(env, v))
                .collect::<Result<_>>()?;
            let mut i = lb;
            while i < ub {
                stats.loop_iterations += 1;
                stats.branches += 1;
                env.insert(iv, Val::I(i));
                for (&cv, &val) in carried.iter().zip(&vals) {
                    env.insert(cv, val);
                }
                match exec_region(func, region, env, mem, stats, pending, trace)? {
                    Some(y) => vals = y,
                    None => return Err(Error::Ir("for body missing yield".into())),
                }
                i += step;
            }
            for (&res, &val) in op.results.iter().zip(&vals) {
                env.insert(res, val);
            }
        }
        OpKind::If => {
            stats.branches += 1;
            let c = get(env, op.operands[0])?.as_i()?;
            let region = if c != 0 { &op.regions[0] } else { &op.regions[1] };
            match exec_region(func, region, env, mem, stats, pending, trace)? {
                Some(vals) => {
                    for (&res, &val) in op.results.iter().zip(&vals) {
                        env.insert(res, val);
                    }
                }
                None => return Err(Error::Ir("if arm missing yield".into())),
            }
        }
        OpKind::Yield | OpKind::Return => {
            let vals: Vec<Val> =
                op.operands.iter().map(|&v| get(env, v)).collect::<Result<_>>()?;
            return Ok(Some(vals));
        }
        OpKind::Intrinsic(name) => {
            stats.intrinsic_calls += 1;
            return Err(Error::Ir(format!(
                "intrinsic `{name}` reached the reference interpreter; lower it or \
                 execute through the ISAX engine"
            )));
        }
    }
    Ok(None)
}

fn do_copy(
    func: &Func,
    mem: &mut Memory,
    dst: BufferId,
    dst_off: i64,
    src: BufferId,
    src_off: i64,
    size: usize,
) -> Result<()> {
    // Offsets/sizes are in bytes; elements are 4 bytes.
    if size % 4 != 0 || dst_off % 4 != 0 || src_off % 4 != 0 {
        return Err(Error::Ir("transfer offsets/size must be 4B-aligned".into()));
    }
    let n = size / 4;
    let d0 = (dst_off / 4) as usize;
    let s0 = (src_off / 4) as usize;
    let dlen = func.buffer(dst).len;
    let slen = func.buffer(src).len;
    if d0 + n > dlen || s0 + n > slen {
        return Err(Error::Ir(format!(
            "transfer out of bounds: dst {d0}+{n}>{dlen} or src {s0}+{n}>{slen}"
        )));
    }
    for i in 0..n {
        let v = mem.get(src, (s0 + i) as i64, slen)?;
        mem.set(dst, (d0 + i) as i64, dlen, v)?;
    }
    Ok(())
}

fn int_bin(kind: &OpKind, x: i64, y: i64) -> Result<i64> {
    Ok(match kind {
        OpKind::Add => x.wrapping_add(y),
        OpKind::Sub => x.wrapping_sub(y),
        OpKind::Mul => x.wrapping_mul(y),
        OpKind::Div => {
            if y == 0 {
                return Err(Error::Ir("division by zero".into()));
            }
            x / y
        }
        OpKind::Min => x.min(y),
        OpKind::Max => x.max(y),
        _ => unreachable!(),
    })
}

fn float_bin(kind: &OpKind, x: f64, y: f64) -> f64 {
    match kind {
        OpKind::Add => x + y,
        OpKind::Sub => x - y,
        OpKind::Mul => x * y,
        OpKind::Div => x / y,
        OpKind::Min => x.min(y),
        OpKind::Max => x.max(y),
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::ir::types::Type;

    #[test]
    fn sum_loop() {
        let mut b = FuncBuilder::new("sum");
        let buf = b.global("x", DType::I32, 8, CacheHint::Unknown);
        let zero = b.const_i(0);
        let lb = b.const_i(0);
        let ub = b.const_i(8);
        let one = b.const_i(1);
        let sums = b.for_loop(lb, ub, one, &[zero], |b, iv, carried| {
            let x = b.load(buf, iv);
            vec![b.add(carried[0], x)]
        });
        let f = b.finish(&sums);
        let mut mem = Memory::for_func(&f);
        mem.write_i32(crate::ir::func::BufferId(0), &[1, 2, 3, 4, 5, 6, 7, 8]);
        let out = run(&f, &[], &mut mem).unwrap();
        assert_eq!(out, vec![Val::I(36)]);
    }

    #[test]
    fn transfer_moves_bytes() {
        let mut b = FuncBuilder::new("t");
        let g = b.global("g", DType::F32, 16, CacheHint::Cold);
        let s = b.scratchpad("s", DType::F32, 16, 1);
        let zero = b.const_i(0);
        b.transfer(s, zero, g, zero, 16 * 4);
        let f = b.finish(&[]);
        let mut mem = Memory::for_func(&f);
        let data: Vec<f32> = (0..16).map(|i| i as f32).collect();
        mem.write_f32(crate::ir::func::BufferId(0), &data);
        run(&f, &[], &mut mem).unwrap();
        assert_eq!(mem.read_f32(crate::ir::func::BufferId(1)), data);
    }

    #[test]
    fn issue_wait_pairs_complete_at_wait() {
        use crate::interface::model::InterfaceId;
        use crate::interface::TransactionKind;
        let mut b = FuncBuilder::new("t");
        let g = b.global("g", DType::I32, 4, CacheHint::Unknown);
        let s = b.scratchpad("s", DType::I32, 4, 1);
        let zero = b.const_i(0);
        // hand-emit issue/wait
        let mut f = {
            b.transfer(s, zero, g, zero, 0); // placeholder replaced below
            b.finish(&[])
        };
        // Replace the placeholder transfer with issue+wait ops.
        let issue = f.add_op(Op::new(
            OpKind::CopyIssue {
                itfc: InterfaceId(0),
                dst: crate::ir::func::BufferId(1),
                src: crate::ir::func::BufferId(0),
                size: 16,
                kind: TransactionKind::Load,
                tag: 7,
                after: vec![],
            },
            vec![Value(0), Value(0)],
            vec![],
        ));
        let wait = f.add_op(Op::new(OpKind::CopyWait { tag: 7 }, vec![], vec![]));
        let ret = f.entry.ops.pop().unwrap(); // return
        f.entry.ops.pop(); // placeholder transfer
        f.entry.ops.push(issue);
        f.entry.ops.push(wait);
        f.entry.ops.push(ret);

        let mut mem = Memory::for_func(&f);
        mem.write_i32(crate::ir::func::BufferId(0), &[9, 8, 7, 6]);
        run(&f, &[], &mut mem).unwrap();
        assert_eq!(mem.read_i32(crate::ir::func::BufferId(1)), vec![9, 8, 7, 6]);
    }

    #[test]
    fn if_else_selects_arm() {
        let mut b = FuncBuilder::new("t");
        let p = b.param(Type::Int);
        let zero = b.const_i(0);
        let c = b.cmp(CmpPred::Gt, p, zero);
        let r = b.if_else(c, |b| vec![b.const_i(10)], |b| vec![b.const_i(20)]);
        let f = b.finish(&r);
        let mut mem = Memory::for_func(&f);
        assert_eq!(run(&f, &[Val::I(5)], &mut mem).unwrap(), vec![Val::I(10)]);
        assert_eq!(run(&f, &[Val::I(-5)], &mut mem).unwrap(), vec![Val::I(20)]);
    }

    #[test]
    fn stats_count_work() {
        let mut b = FuncBuilder::new("t");
        let buf = b.global("x", DType::I32, 4, CacheHint::Unknown);
        b.for_range(0, 4, 1, |b, iv| {
            let v = b.load(buf, iv);
            let one = b.const_i(1);
            let w = b.add(v, one);
            b.store(buf, iv, w);
        });
        let f = b.finish(&[]);
        let mut mem = Memory::for_func(&f);
        let mut stats = ExecStats::default();
        run_with_stats(&f, &[], &mut mem, &mut stats).unwrap();
        assert_eq!(stats.loads, 4);
        assert_eq!(stats.stores, 4);
        assert_eq!(stats.loop_iterations, 4);
        assert_eq!(stats.arith_ops, 4);
    }

    #[test]
    fn out_of_bounds_rejected() {
        let mut b = FuncBuilder::new("t");
        let buf = b.global("x", DType::I32, 2, CacheHint::Unknown);
        let idx = b.const_i(5);
        let v = b.load(buf, idx);
        let f = b.finish(&[v]);
        let mut mem = Memory::for_func(&f);
        assert!(run(&f, &[], &mut mem).is_err());
    }
}
