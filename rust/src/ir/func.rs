//! Function/module containers: op arena, regions, buffers, SSA values.

use crate::interface::cache::CacheHint;
use crate::ir::ops::{Op, OpKind};
use crate::ir::types::Type;
use crate::runtime::DType;

/// SSA value id (index into the function's value-type table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Value(pub u32);

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "%{}", self.0)
    }
}

/// Op id (index into the function's op arena).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpRef(pub u32);

/// Buffer id (index into the function's buffer table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BufferId(pub u32);

/// What backs a buffer symbol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufferKind {
    /// Global (main) memory visible to the CPU and the ISAX.
    Global,
    /// An explicit ISAX-local scratchpad (SRAM); `banks` is the banking
    /// factor hwgen will synthesize.
    Scratchpad { banks: usize },
}

/// A module-level memory symbol: global region or local scratchpad.
#[derive(Debug, Clone, PartialEq)]
pub struct BufferDecl {
    pub name: String,
    pub kind: BufferKind,
    /// Element type (drives byte sizing: f32/i32 are 4 bytes each).
    pub elem: DType,
    /// Element count.
    pub len: usize,
    /// §4.1 cache_hint label.
    pub hint: CacheHint,
    /// Byte address of the buffer base in the flat global address space
    /// used by alignment-aware canonicalization (scratchpads ignore it).
    pub base_addr: u64,
}

impl BufferDecl {
    pub fn size_bytes(&self) -> usize {
        self.len * 4
    }
}

/// A single-block region: an ordered list of ops plus region parameters
/// (loop induction variable + iter_args for `for`; empty for `if` arms).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Region {
    pub params: Vec<Value>,
    pub ops: Vec<OpRef>,
}

/// A function: op arena + entry region + buffers + value types.
#[derive(Debug, Clone, PartialEq)]
pub struct Func {
    pub name: String,
    /// Function parameters (scalar arguments, e.g. sizes or rs1/rs2).
    pub params: Vec<Value>,
    pub entry: Region,
    ops: Vec<Op>,
    value_types: Vec<Type>,
    pub buffers: Vec<BufferDecl>,
}

impl Func {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            params: Vec::new(),
            entry: Region::default(),
            ops: Vec::new(),
            value_types: Vec::new(),
            buffers: Vec::new(),
        }
    }

    /// Allocate a fresh SSA value of `ty`.
    pub fn new_value(&mut self, ty: Type) -> Value {
        let v = Value(self.value_types.len() as u32);
        self.value_types.push(ty);
        v
    }

    /// Append an op to the arena (not yet inserted in any region).
    pub fn add_op(&mut self, op: Op) -> OpRef {
        let r = OpRef(self.ops.len() as u32);
        self.ops.push(op);
        r
    }

    pub fn op(&self, r: OpRef) -> &Op {
        &self.ops[r.0 as usize]
    }

    pub fn op_mut(&mut self, r: OpRef) -> &mut Op {
        &mut self.ops[r.0 as usize]
    }

    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    pub fn value_type(&self, v: Value) -> Type {
        self.value_types[v.0 as usize]
    }

    pub fn num_values(&self) -> usize {
        self.value_types.len()
    }

    pub fn buffer(&self, b: BufferId) -> &BufferDecl {
        &self.buffers[b.0 as usize]
    }

    pub fn buffer_mut(&mut self, b: BufferId) -> &mut BufferDecl {
        &mut self.buffers[b.0 as usize]
    }

    /// Declare a buffer symbol; returns its id.
    pub fn add_buffer(&mut self, decl: BufferDecl) -> BufferId {
        let id = BufferId(self.buffers.len() as u32);
        self.buffers.push(decl);
        id
    }

    /// Find a buffer by name.
    pub fn buffer_by_name(&self, name: &str) -> Option<BufferId> {
        self.buffers
            .iter()
            .position(|b| b.name == name)
            .map(|i| BufferId(i as u32))
    }

    /// Walk all ops of a region recursively (pre-order), calling `f`.
    pub fn walk_region<F: FnMut(OpRef, &Op)>(&self, region: &Region, f: &mut F) {
        for &opref in &region.ops {
            let op = self.op(opref);
            f(opref, op);
            for r in &op.regions {
                self.walk_region(r, f);
            }
        }
    }

    /// Walk the whole function.
    pub fn walk<F: FnMut(OpRef, &Op)>(&self, mut f: F) {
        let entry = self.entry.clone();
        self.walk_region(&entry, &mut f);
    }

    /// Count ops of a given predicate in the whole function.
    pub fn count_ops<F: Fn(&OpKind) -> bool>(&self, pred: F) -> usize {
        let mut n = 0;
        self.walk(|_, op| {
            if pred(&op.kind) {
                n += 1;
            }
        });
        n
    }

    /// Rewrite every operand of every op through `map`, resolving chains
    /// (`a -> b`, `b -> c` sends uses of `a` to `c`). Results and region
    /// params are never rewritten — the map replaces *uses*, so the
    /// mid-end passes can retire an op by mapping its results to an
    /// equivalent value and dropping its `OpRef` from the owning region.
    pub fn replace_uses(&mut self, map: &std::collections::HashMap<Value, Value>) {
        if map.is_empty() {
            return;
        }
        let resolve = |mut v: Value| {
            // Chains are short (CSE/SCCP build them one hop at a time);
            // bound the walk by the map size to stay safe on cycles.
            let mut hops = 0;
            while let Some(&n) = map.get(&v) {
                v = n;
                hops += 1;
                if hops > map.len() {
                    break;
                }
            }
            v
        };
        for op in &mut self.ops {
            for operand in &mut op.operands {
                *operand = resolve(*operand);
            }
        }
    }

    /// Producer map: which op defines each value (region params map to the
    /// op owning the region; function params map to None).
    pub fn def_map(&self) -> Vec<Option<OpRef>> {
        let mut defs: Vec<Option<OpRef>> = vec![None; self.value_types.len()];
        for (i, op) in self.ops.iter().enumerate() {
            for &r in &op.results {
                defs[r.0 as usize] = Some(OpRef(i as u32));
            }
            for region in &op.regions {
                for &p in &region.params {
                    defs[p.0 as usize] = Some(OpRef(i as u32));
                }
            }
        }
        defs
    }
}
