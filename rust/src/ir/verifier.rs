//! IR verifier: SSA dominance, operand arity/typing, region structure,
//! buffer references, and level consistency (a function must not mix
//! functional `transfer` with temporal `copy_issue` — synthesis lowers
//! level by level).

use std::collections::HashSet;

use crate::error::{Error, Result};
use crate::ir::func::{Func, Region, Value};
use crate::ir::ops::{Op, OpKind};
use crate::ir::types::Type;

/// Which Aquas-IR level a function sits at (software counts as functional
/// for mixing purposes: both are pre-binding).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum IrLevel {
    Functional,
    Architectural,
    Temporal,
}

/// Classify an op's level (dataflow/control ops are level-neutral).
pub fn op_level(kind: &OpKind) -> Option<IrLevel> {
    match kind {
        OpKind::Transfer { .. } | OpKind::Fetch(_) => Some(IrLevel::Functional),
        OpKind::Copy { .. } | OpKind::LoadItfc { .. } | OpKind::StoreItfc { .. } => {
            Some(IrLevel::Architectural)
        }
        OpKind::CopyIssue { .. } | OpKind::CopyWait { .. } => Some(IrLevel::Temporal),
        _ => None,
    }
}

/// The highest (most-refined) level present in a function.
pub fn func_level(f: &Func) -> IrLevel {
    let mut level = IrLevel::Functional;
    f.walk(|_, op| {
        if let Some(l) = op_level(&op.kind) {
            level = level.max(l);
        }
    });
    level
}

/// Verify a function after a mid-end optimization pass, wrapping any
/// failure with the pass name so pipeline debugging points straight at
/// the offending stage. The pass pipeline ([`crate::ir::passes`]) calls
/// this after every pass it runs — a pass that produces un-verifiable IR
/// is a bug in the pass, never a runtime surprise downstream.
pub fn verify_after_pass(f: &Func, pass: &str) -> Result<()> {
    verify(f).map_err(|e| Error::Ir(format!("post-{pass} verification failed: {e}")))
}

/// Verify a function; returns the first problem found.
pub fn verify(f: &Func) -> Result<()> {
    let mut scope: HashSet<Value> = f.params.iter().copied().collect();
    verify_region(f, &f.entry, &mut scope, true)?;
    verify_buffers(f)?;
    verify_no_level_mixing(f)?;
    Ok(())
}

fn verify_region(
    f: &Func,
    region: &Region,
    scope: &mut HashSet<Value>,
    is_entry: bool,
) -> Result<()> {
    for &p in &region.params {
        if !scope.insert(p) {
            return Err(Error::Ir(format!("region param {p} redefined")));
        }
    }
    let mut terminated = false;
    for &opref in &region.ops {
        let op = f.op(opref);
        if terminated {
            return Err(Error::Ir(format!(
                "op {} after region terminator",
                op.kind.mnemonic()
            )));
        }
        // Operand visibility (dominance in a structured IR = lexical scope).
        for &v in &op.operands {
            if !scope.contains(&v) {
                return Err(Error::Ir(format!(
                    "operand {v} of {} not in scope",
                    op.kind.mnemonic()
                )));
            }
        }
        check_arity(f, op)?;
        // Regions see the enclosing scope.
        for r in &op.regions {
            let mut inner = scope.clone();
            verify_region(f, r, &mut inner, false)?;
        }
        for &r in &op.results {
            if !scope.insert(r) {
                return Err(Error::Ir(format!("value {r} redefined")));
            }
        }
        if matches!(op.kind, OpKind::Yield | OpKind::Return) {
            terminated = true;
            let want_return = is_entry;
            let is_return = matches!(op.kind, OpKind::Return);
            if want_return != is_return {
                return Err(Error::Ir(format!(
                    "region terminator mismatch: entry={want_return} got {}",
                    op.kind.mnemonic()
                )));
            }
        }
    }
    if !terminated {
        return Err(Error::Ir("region missing terminator".into()));
    }
    Ok(())
}

fn check_arity(f: &Func, op: &Op) -> Result<()> {
    let (min_in, n_out): (usize, usize) = match &op.kind {
        OpKind::ConstI(_) | OpKind::ConstF(_) | OpKind::ReadIrf(_) => (0, 1),
        OpKind::Add
        | OpKind::Sub
        | OpKind::Mul
        | OpKind::Div
        | OpKind::Rem
        | OpKind::Shl
        | OpKind::Shr
        | OpKind::And
        | OpKind::Or
        | OpKind::Xor
        | OpKind::Min
        | OpKind::Max
        | OpKind::Cmp(_) => (2, 1),
        OpKind::Neg
        | OpKind::Sqrt
        | OpKind::Exp
        | OpKind::Powi(_)
        | OpKind::ToFloat
        | OpKind::ToInt => (1, 1),
        OpKind::Select => (3, 1),
        OpKind::Load(_) | OpKind::Fetch(_) | OpKind::ReadSmem(_) => (1, 1),
        OpKind::LoadItfc { .. } => (1, 1),
        OpKind::Store(_) | OpKind::WriteSmem(_) | OpKind::StoreItfc { .. } => (2, 0),
        OpKind::WriteIrf(_) => (1, 0),
        OpKind::Transfer { .. } | OpKind::Copy { .. } | OpKind::CopyIssue { .. } => (2, 0),
        OpKind::CopyWait { .. } => (0, 0),
        OpKind::For => {
            if op.regions.len() != 1 {
                return Err(Error::Ir("for must have exactly one region".into()));
            }
            let carried = op.operands.len().saturating_sub(3);
            if op.regions[0].params.len() != carried + 1 {
                return Err(Error::Ir(format!(
                    "for region params {} != iv + {} carried",
                    op.regions[0].params.len(),
                    carried
                )));
            }
            if op.results.len() != carried {
                return Err(Error::Ir("for results != carried count".into()));
            }
            if op.operands.len() < 3 {
                return Err(Error::Ir("for needs lb, ub, step".into()));
            }
            return Ok(());
        }
        OpKind::If => {
            if op.regions.len() != 2 {
                return Err(Error::Ir("if must have two regions".into()));
            }
            if op.operands.len() != 1 {
                return Err(Error::Ir("if takes exactly one condition".into()));
            }
            return Ok(());
        }
        OpKind::Yield | OpKind::Return | OpKind::Intrinsic(_) => return Ok(()),
    };
    if op.operands.len() != min_in {
        return Err(Error::Ir(format!(
            "{}: expected {min_in} operands, got {}",
            op.kind.mnemonic(),
            op.operands.len()
        )));
    }
    if op.results.len() != n_out {
        return Err(Error::Ir(format!(
            "{}: expected {n_out} results, got {}",
            op.kind.mnemonic(),
            op.results.len()
        )));
    }
    // Light type checks: indices and shift amounts must be Int.
    match &op.kind {
        OpKind::Load(_) | OpKind::Fetch(_) | OpKind::ReadSmem(_) | OpKind::LoadItfc { .. } => {
            if f.value_type(op.operands[0]) != Type::Int {
                return Err(Error::Ir(format!("{}: index must be Int", op.kind.mnemonic())));
            }
        }
        OpKind::Shl | OpKind::Shr | OpKind::Rem => {
            if f.value_type(op.operands[0]) != Type::Int {
                return Err(Error::Ir(format!("{}: operands must be Int", op.kind.mnemonic())));
            }
        }
        _ => {}
    }
    Ok(())
}

fn verify_buffers(f: &Func) -> Result<()> {
    let n = f.buffers.len() as u32;
    let mut bad = None;
    f.walk(|_, op| {
        let check = |b: crate::ir::func::BufferId| b.0 >= n;
        let out_of_range = match &op.kind {
            OpKind::Load(b)
            | OpKind::Store(b)
            | OpKind::Fetch(b)
            | OpKind::ReadSmem(b)
            | OpKind::WriteSmem(b) => check(*b),
            OpKind::Transfer { dst, src, .. } => check(*dst) || check(*src),
            OpKind::Copy { dst, src, .. } | OpKind::CopyIssue { dst, src, .. } => {
                check(*dst) || check(*src)
            }
            OpKind::LoadItfc { buf, .. } | OpKind::StoreItfc { buf, .. } => check(*buf),
            _ => false,
        };
        if out_of_range && bad.is_none() {
            bad = Some(op.kind.mnemonic());
        }
    });
    match bad {
        Some(m) => Err(Error::Ir(format!("{m}: buffer id out of range"))),
        None => Ok(()),
    }
}

fn verify_no_level_mixing(f: &Func) -> Result<()> {
    let mut has_functional = false;
    let mut has_temporal = false;
    f.walk(|_, op| match op_level(&op.kind) {
        Some(IrLevel::Functional) => has_functional = true,
        Some(IrLevel::Temporal) => has_temporal = true,
        _ => {}
    });
    if has_functional && has_temporal {
        return Err(Error::Ir(
            "function mixes functional (transfer/fetch) and temporal (copy_issue) ops".into(),
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    #[test]
    fn valid_function_passes() {
        let mut b = FuncBuilder::new("ok");
        let buf = b.global("x", DType::F32, 8, CacheHint::Unknown);
        b.for_range(0, 8, 1, |b, iv| {
            let v = b.load(buf, iv);
            b.store(buf, iv, v);
        });
        let f = b.finish(&[]);
        verify(&f).unwrap();
        assert_eq!(func_level(&f), IrLevel::Functional);
    }

    #[test]
    fn detects_out_of_scope_operand() {
        use crate::ir::ops::{Op, OpKind};
        let mut f = Func::new("bad");
        let ghost = Value(99);
        // manually add op with unknown operand
        let r = f.new_value(Type::Int);
        let op = f.add_op(Op::new(OpKind::Neg, vec![ghost], vec![r]));
        f.entry.ops.push(op);
        let ret = f.add_op(Op::new(OpKind::Return, vec![], vec![]));
        f.entry.ops.push(ret);
        // value table too small -> still out of scope
        assert!(verify(&f).is_err());
    }

    #[test]
    fn detects_missing_terminator() {
        let mut f = Func::new("noterm");
        let v = f.new_value(Type::Int);
        let op = f.add_op(crate::ir::ops::Op::new(OpKind::ConstI(1), vec![], vec![v]));
        f.entry.ops.push(op);
        assert!(verify(&f).is_err());
    }

    #[test]
    fn detects_level_mixing() {
        use crate::interface::model::InterfaceId;
        use crate::interface::TransactionKind;
        let mut b = FuncBuilder::new("mixed");
        let g = b.global("g", DType::F32, 64, CacheHint::Unknown);
        let s = b.scratchpad("s", DType::F32, 64, 1);
        let zero = b.const_i(0);
        b.transfer(s, zero, g, zero, 64);
        let f_ok = {
            // temporal op added manually to force the mix
            let mut f = b.finish(&[]);
            let op = f.add_op(crate::ir::ops::Op::new(
                OpKind::CopyIssue {
                    itfc: InterfaceId(0),
                    dst: crate::ir::func::BufferId(1),
                    src: crate::ir::func::BufferId(0),
                    size: 4,
                    kind: TransactionKind::Load,
                    tag: 0,
                    after: vec![],
                },
                vec![Value(0), Value(0)],
                vec![],
            ));
            // insert after const+transfer, before return, so scope is fine
            // and the only failure is the level mix.
            let at = f.entry.ops.len() - 1;
            f.entry.ops.insert(at, op);
            f
        };
        let err = verify(&f_ok).unwrap_err().to_string();
        assert!(err.contains("mixes functional"), "got: {err}");
    }
}
