//! Affine index analysis.
//!
//! Used by two parts of the paper's flow:
//! - **synthesis** (§4.3): scratchpad-elision legality needs to know the
//!   access pattern of each buffer index (affine ⇒ predictable stride ⇒
//!   no cache thrashing after elision);
//! - **compiler** (§5.3): the e-graph cost model "penalizes non-affine
//!   operations" so extraction steers toward affine-friendly variants
//!   (e.g. `i*4` over `i<<2`) that MLIR-style loop passes can transform.
//!
//! An expression is affine in a set of loop induction variables if it is
//! built from constants, ivs, addition/subtraction, and multiplication by
//! a constant. `Shl` is deliberately classified non-affine, mirroring the
//! paper's example where `i << 2` blocks loop analysis until rewritten.

use std::collections::HashMap;

use crate::ir::func::{Func, OpRef, Region, Value};
use crate::ir::ops::OpKind;

/// A linear form `c0 + Σ ci·iv_i` over loop induction variables.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct AffineExpr {
    /// Constant term.
    pub constant: i64,
    /// Coefficient per induction variable.
    pub coeffs: HashMap<Value, i64>,
}

impl AffineExpr {
    pub fn constant(c: i64) -> Self {
        Self { constant: c, coeffs: HashMap::new() }
    }

    pub fn var(v: Value) -> Self {
        let mut coeffs = HashMap::new();
        coeffs.insert(v, 1);
        Self { constant: 0, coeffs }
    }

    pub fn is_constant(&self) -> bool {
        self.coeffs.values().all(|&c| c == 0)
    }

    /// Stride with respect to one induction variable.
    pub fn stride_of(&self, iv: Value) -> i64 {
        self.coeffs.get(&iv).copied().unwrap_or(0)
    }

    fn add(&self, other: &Self) -> Self {
        let mut out = self.clone();
        out.constant += other.constant;
        for (v, c) in &other.coeffs {
            *out.coeffs.entry(*v).or_insert(0) += c;
        }
        out
    }

    fn sub(&self, other: &Self) -> Self {
        let mut out = self.clone();
        out.constant -= other.constant;
        for (v, c) in &other.coeffs {
            *out.coeffs.entry(*v).or_insert(0) -= c;
        }
        out
    }

    fn scale(&self, k: i64) -> Self {
        let mut out = self.clone();
        out.constant *= k;
        for c in out.coeffs.values_mut() {
            *c *= k;
        }
        out
    }
}

/// Affine analysis over one function. Induction variables are the region
/// params of `for` ops; everything derived affinely from them is tracked.
pub struct AffineAnalysis<'f> {
    func: &'f Func,
    exprs: HashMap<Value, AffineExpr>,
}

impl<'f> AffineAnalysis<'f> {
    /// Run the analysis (single forward pass; the IR is structured so defs
    /// dominate uses lexically).
    pub fn run(func: &'f Func) -> Self {
        let mut a = Self { func, exprs: HashMap::new() };
        a.visit_region(&func.entry);
        a
    }

    /// The affine form of a value, if it has one.
    pub fn expr(&self, v: Value) -> Option<&AffineExpr> {
        self.exprs.get(&v)
    }

    /// Is this value affine in the enclosing induction variables?
    pub fn is_affine(&self, v: Value) -> bool {
        self.exprs.contains_key(&v)
    }

    fn visit_region(&mut self, region: &Region) {
        for &opref in &region.ops {
            self.visit_op(opref);
        }
    }

    fn visit_op(&mut self, opref: OpRef) {
        let op = self.func.op(opref).clone();
        match &op.kind {
            OpKind::ConstI(c) => {
                self.exprs.insert(op.results[0], AffineExpr::constant(*c));
            }
            OpKind::For => {
                // iv is affine (a fresh variable); carried values are not
                // tracked (they may be arbitrary reductions).
                let iv = op.regions[0].params[0];
                self.exprs.insert(iv, AffineExpr::var(iv));
                self.visit_region(&op.regions[0]);
            }
            OpKind::If => {
                self.visit_region(&op.regions[0]);
                self.visit_region(&op.regions[1]);
            }
            OpKind::Add => self.binary(&op, |a, b| Some(a.add(b))),
            OpKind::Sub => self.binary(&op, |a, b| Some(a.sub(b))),
            OpKind::Mul => self.binary(&op, |a, b| {
                if a.is_constant() {
                    Some(b.scale(a.constant))
                } else if b.is_constant() {
                    Some(a.scale(b.constant))
                } else {
                    None
                }
            }),
            // Shl/Shr/Div/Rem etc. are conservatively non-affine (§5.3).
            _ => {
                for r in &op.regions {
                    self.visit_region(r);
                }
            }
        }
    }

    fn binary<F>(&mut self, op: &crate::ir::ops::Op, f: F)
    where
        F: FnOnce(&AffineExpr, &AffineExpr) -> Option<AffineExpr>,
    {
        let (a, b) = (op.operands[0], op.operands[1]);
        if let (Some(ea), Some(eb)) = (self.exprs.get(&a), self.exprs.get(&b)) {
            if let Some(e) = f(ea, eb) {
                self.exprs.insert(op.results[0], e);
            }
        }
    }
}

/// Summary of how a buffer is accessed inside a function: used by elision.
#[derive(Debug, Clone, Default)]
pub struct AccessPattern {
    /// Number of read sites (load/read_smem/fetch).
    pub reads: usize,
    /// Number of write sites.
    pub writes: usize,
    /// All access indices were affine in the loop ivs.
    pub all_affine: bool,
    /// Minimum absolute iv stride over affine accesses (0 = loop-invariant).
    pub min_stride: i64,
    /// Max absolute stride.
    pub max_stride: i64,
}

/// Analyze how `buf` is accessed within `func`.
pub fn access_pattern(func: &Func, buf: crate::ir::func::BufferId) -> AccessPattern {
    let analysis = AffineAnalysis::run(func);
    let mut pat = AccessPattern { all_affine: true, min_stride: i64::MAX, ..Default::default() };
    func.walk(|_, op| {
        let (is_read, is_write, index) = match &op.kind {
            OpKind::Load(b) | OpKind::Fetch(b) | OpKind::ReadSmem(b) if *b == buf => {
                (true, false, Some(op.operands[0]))
            }
            OpKind::Store(b) | OpKind::WriteSmem(b) if *b == buf => {
                (false, true, Some(op.operands[0]))
            }
            _ => (false, false, None),
        };
        if let Some(idx) = index {
            if is_read {
                pat.reads += 1;
            }
            if is_write {
                pat.writes += 1;
            }
            match analysis.expr(idx) {
                Some(e) => {
                    let strides: Vec<i64> =
                        e.coeffs.values().map(|c| c.abs()).filter(|&c| c != 0).collect();
                    let s = strides.into_iter().max().unwrap_or(0);
                    pat.min_stride = pat.min_stride.min(s);
                    pat.max_stride = pat.max_stride.max(s);
                }
                None => pat.all_affine = false,
            }
        }
    });
    if pat.min_stride == i64::MAX {
        pat.min_stride = 0;
    }
    pat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    #[test]
    fn iv_times_constant_is_affine() {
        let mut b = FuncBuilder::new("t");
        let buf = b.global("x", DType::I32, 64, CacheHint::Unknown);
        b.for_range(0, 16, 1, |b, iv| {
            let four = b.const_i(4);
            let idx = b.mul(iv, four);
            let v = b.load(buf, idx);
            b.store(buf, idx, v);
        });
        let f = b.finish(&[]);
        let pat = access_pattern(&f, crate::ir::func::BufferId(0));
        assert!(pat.all_affine);
        assert_eq!(pat.max_stride, 4);
        assert_eq!(pat.reads, 1);
        assert_eq!(pat.writes, 1);
    }

    #[test]
    fn shl_is_non_affine() {
        let mut b = FuncBuilder::new("t");
        let buf = b.global("x", DType::I32, 64, CacheHint::Unknown);
        b.for_range(0, 16, 1, |b, iv| {
            let two = b.const_i(2);
            let idx = b.shl(iv, two); // i << 2 — the §5.3 example
            let v = b.load(buf, idx);
            b.store(buf, idx, v);
        });
        let f = b.finish(&[]);
        let pat = access_pattern(&f, crate::ir::func::BufferId(0));
        assert!(!pat.all_affine);
    }

    #[test]
    fn nested_ivs_compose() {
        let mut b = FuncBuilder::new("t");
        let buf = b.global("x", DType::I32, 256, CacheHint::Unknown);
        b.for_range(0, 4, 1, |b, i| {
            b.for_range(0, 8, 1, |b, j| {
                let eight = b.const_i(8);
                let row = b.mul(i, eight);
                let idx = b.add(row, j);
                let v = b.load(buf, idx);
                b.store(buf, idx, v);
            });
        });
        let f = b.finish(&[]);
        let pat = access_pattern(&f, crate::ir::func::BufferId(0));
        assert!(pat.all_affine);
        assert_eq!(pat.max_stride, 8);
    }

    #[test]
    fn loop_invariant_access_has_zero_stride() {
        let mut b = FuncBuilder::new("t");
        let buf = b.global("x", DType::I32, 16, CacheHint::Unknown);
        b.for_range(0, 16, 1, |b, _iv| {
            let zero = b.const_i(0);
            let v = b.load(buf, zero);
            b.store(buf, zero, v);
        });
        let f = b.finish(&[]);
        let pat = access_pattern(&f, crate::ir::func::BufferId(0));
        assert!(pat.all_affine);
        assert_eq!(pat.max_stride, 0);
    }
}
