//! Operation kinds across all Aquas-IR levels plus the software dialect.

use crate::interface::model::InterfaceId;
use crate::interface::TransactionKind;
use crate::ir::func::{BufferId, Region, Value};

/// Comparison predicates for `Cmp`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpPred {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

/// Every operation kind. Operand/result arity conventions are documented
/// per variant; the verifier enforces them.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    // ----- dataflow (software + all hardware levels) ---------------------
    /// Integer constant. `() -> int`
    ConstI(i64),
    /// Float constant. `() -> float`
    ConstF(f64),
    /// `(a, b) -> r`; polymorphic over Int/Float (operands must agree).
    Add,
    Sub,
    Mul,
    /// Signed division (Int) / fp division (Float).
    Div,
    /// Remainder (Int only).
    Rem,
    /// Shift left (Int only) — note: deliberately *not* affine-friendly;
    /// the §5.3 example rewrites `i << 2` into `i * 4`.
    Shl,
    /// Arithmetic shift right (Int only).
    Shr,
    And,
    Or,
    Xor,
    Min,
    Max,
    /// `(a) -> r` negate.
    Neg,
    /// Comparison. `(a, b) -> int(0|1)`
    Cmp(CmpPred),
    /// `(cond, a, b) -> r`
    Select,
    /// Square root (Float).
    Sqrt,
    /// Natural exponential (Float) — the softmax/SwiGLU primitive that
    /// lets attention run fully in-IR (see `workloads::llm`).
    Exp,
    /// Power with constant integer exponent (graphics: shininess).
    Powi(u32),
    /// Int -> Float.
    ToFloat,
    /// Float -> Int (truncating).
    ToInt,

    // ----- software-level memory ----------------------------------------
    /// Load one element. `(index) -> value`; buffer's elem type decides.
    Load(BufferId),
    /// Store one element. `(index, value) -> ()`
    Store(BufferId),

    // ----- Aquas-IR functional level (§4.2) ------------------------------
    /// Mechanism-agnostic bulk transfer of `size` bytes:
    /// `(dst_off, src_off) -> ()` with `dst`/`src` buffers as attributes.
    Transfer { dst: BufferId, src: BufferId, size: usize },
    /// Mechanism-agnostic single-element fetch from global memory:
    /// `(index) -> value`.
    Fetch(BufferId),
    /// Scratchpad read/write. `(index) -> value` / `(index, value) -> ()`
    ReadSmem(BufferId),
    WriteSmem(BufferId),
    /// Integer register-file access (ISAX operand plumbing).
    /// `() -> value` / `(value) -> ()`
    ReadIrf(u8),
    WriteIrf(u8),

    // ----- Aquas-IR architectural level ----------------------------------
    /// Interface-bound bulk copy (one legal transaction of `size` bytes):
    /// `(dst_off, src_off) -> ()`.
    Copy {
        itfc: InterfaceId,
        dst: BufferId,
        src: BufferId,
        size: usize,
        kind: TransactionKind,
    },
    /// Interface-bound scalar access: `(index) -> value`.
    LoadItfc { itfc: InterfaceId, buf: BufferId },
    /// `(index, value) -> ()`.
    StoreItfc { itfc: InterfaceId, buf: BufferId },

    // ----- Aquas-IR temporal level ----------------------------------------
    /// Asynchronous issue of a decomposed transaction. `tag` names the
    /// transaction; `after` lists tags that must issue before this one
    /// (the paper's `after` attribute). `(dst_off, src_off) -> ()`
    CopyIssue {
        itfc: InterfaceId,
        dst: BufferId,
        src: BufferId,
        size: usize,
        kind: TransactionKind,
        tag: u32,
        after: Vec<u32>,
    },
    /// Wait for a tagged transaction to complete. `() -> ()`
    CopyWait { tag: u32 },

    // ----- control flow ----------------------------------------------------
    /// `for iv = lb to ub step s iter_args(init...)`:
    /// operands `[lb, ub, step, init...]`, one body region whose params are
    /// `[iv, carried...]`, results = carried-out values.
    For,
    /// `(cond) -> results`; regions `[then, else]`, each ending in Yield.
    If,
    /// Region terminator carrying loop-carried / if results.
    Yield,
    /// Function return.
    Return,
    /// A matched ISAX invocation (§5.4 lowering): `name` identifies the
    /// custom instruction; operands are its software-visible inputs.
    Intrinsic(String),
}

impl OpKind {
    /// Does this op have side effects / impose ordering (an *anchor* in the
    /// §5.2 e-graph encoding)?
    pub fn is_anchor(&self) -> bool {
        matches!(
            self,
            OpKind::Store(_)
                | OpKind::WriteSmem(_)
                | OpKind::WriteIrf(_)
                | OpKind::Transfer { .. }
                | OpKind::Copy { .. }
                | OpKind::StoreItfc { .. }
                | OpKind::CopyIssue { .. }
                | OpKind::CopyWait { .. }
                | OpKind::For
                | OpKind::If
                | OpKind::Yield
                | OpKind::Return
                | OpKind::Intrinsic(_)
        )
    }

    /// Does this op read or write memory at all (used by elision analysis
    /// and the matcher's effect checks)?
    pub fn touches_memory(&self) -> bool {
        matches!(
            self,
            OpKind::Load(_)
                | OpKind::Store(_)
                | OpKind::Fetch(_)
                | OpKind::ReadSmem(_)
                | OpKind::WriteSmem(_)
                | OpKind::Transfer { .. }
                | OpKind::Copy { .. }
                | OpKind::LoadItfc { .. }
                | OpKind::StoreItfc { .. }
                | OpKind::CopyIssue { .. }
        )
    }

    /// Mnemonic used by the printer and the e-graph symbol table.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::ConstI(_) => "const.i",
            OpKind::ConstF(_) => "const.f",
            OpKind::Add => "add",
            OpKind::Sub => "sub",
            OpKind::Mul => "mul",
            OpKind::Div => "div",
            OpKind::Rem => "rem",
            OpKind::Shl => "shl",
            OpKind::Shr => "shr",
            OpKind::And => "and",
            OpKind::Or => "or",
            OpKind::Xor => "xor",
            OpKind::Min => "min",
            OpKind::Max => "max",
            OpKind::Neg => "neg",
            OpKind::Cmp(_) => "cmp",
            OpKind::Select => "select",
            OpKind::Sqrt => "sqrt",
            OpKind::Exp => "exp",
            OpKind::Powi(_) => "powi",
            OpKind::ToFloat => "to_float",
            OpKind::ToInt => "to_int",
            OpKind::Load(_) => "load",
            OpKind::Store(_) => "store",
            OpKind::Transfer { .. } => "transfer",
            OpKind::Fetch(_) => "fetch",
            OpKind::ReadSmem(_) => "read_smem",
            OpKind::WriteSmem(_) => "write_smem",
            OpKind::ReadIrf(_) => "read_irf",
            OpKind::WriteIrf(_) => "write_irf",
            OpKind::Copy { .. } => "copy",
            OpKind::LoadItfc { .. } => "load_itfc",
            OpKind::StoreItfc { .. } => "store_itfc",
            OpKind::CopyIssue { .. } => "copy_issue",
            OpKind::CopyWait { .. } => "copy_wait",
            OpKind::For => "for",
            OpKind::If => "if",
            OpKind::Yield => "yield",
            OpKind::Return => "return",
            OpKind::Intrinsic(_) => "isax",
        }
    }
}

/// One operation: kind + operands + results + nested regions.
#[derive(Debug, Clone, PartialEq)]
pub struct Op {
    pub kind: OpKind,
    pub operands: Vec<Value>,
    pub results: Vec<Value>,
    pub regions: Vec<Region>,
}

impl Op {
    pub fn new(kind: OpKind, operands: Vec<Value>, results: Vec<Value>) -> Self {
        Self { kind, operands, results, regions: Vec::new() }
    }

    /// Single result helper; panics if the op has != 1 results.
    pub fn result(&self) -> Value {
        assert_eq!(self.results.len(), 1, "{:?} has {} results", self.kind, self.results.len());
        self.results[0]
    }
}
