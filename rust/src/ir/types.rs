//! Value types. The IR keeps the type lattice deliberately small: scalar
//! integers and floats (64-bit in the interpreter; hardware width is a
//! synthesis attribute, not a type property), plus `None` for ops without
//! results. Buffers are declared at function scope (see
//! [`crate::ir::func::BufferDecl`]) rather than passed as memref values —
//! this mirrors how ISAX descriptions name scratchpads and interfaces as
//! module-level symbols.

/// Scalar type of an SSA value.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Type {
    /// Integer scalar (modelled as i64; hardware width is an attribute).
    #[default]
    Int,
    /// Floating-point scalar (modelled as f64).
    Float,
    /// No value (results of side-effect-only ops).
    None,
}

impl std::fmt::Display for Type {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Type::Int => write!(f, "i64"),
            Type::Float => write!(f, "f64"),
            Type::None => write!(f, "none"),
        }
    }
}
