//! `aquas` CLI — synth / compile / sim / serve / bench.
//!
//! Hand-rolled argument parsing (clap is not in the offline vendor set;
//! see DESIGN.md).

use aquas::bench_harness as bh;
use aquas::coordinator::{
    Coordinator, CoordinatorConfig, FaultPlan, SchedulePolicy, SocConfig, SocCoordinator,
    TraceRequest, TraceSpec,
};
use aquas::runtime::Runtime;

const USAGE: &str = "\
aquas — holistic hardware-software co-optimization for ASIPs (paper repro)

USAGE:
    aquas <COMMAND> [ARGS]

COMMANDS:
    synth --demo fir7         show the fir7 IR after each synthesis stage
                              (Figure 4) + generated structural Verilog
                              --timing sim   replay the chosen transaction
                              schedule through the event-driven burst-DMA
                              simulator and report closed-form vs
                              simulated cycles per interface
    compile <kernel>          compile one case-study kernel against its
                              ISAX and print the Table-3 statistics
                              (kernels: vdecomp mgf2mm vdist3.vv mcov.vs
                               vfsmax vmadot vmvar mphong vrgb2yuv)
                              --opt-level 0|2   run the mid-end pass
                              pipeline (SCCP/CSE/LICM/sink/DCE) on the
                              lowered program (default 0)
                              --budget SPEC     cap compile-side work,
                              e.g. iters=4,nodes=20000,matches=1000,
                              external=2,rounds=8 — exhaustion degrades
                              the match, never fails the compile
    opt --demo                show the mid-end pass pipeline on a demo
                              function: IR before/after, per-pass rewrite
                              counts, and the dynamic-op-count delta
    bench <what>              regenerate a table/figure:
                              table2 | table3 | fig2 | fig3 | fig6 | fig7 | fig8 | all
                              (engine microbenches: egraph | serve | interp | dma | dse)
    explore [OPTIONS]         automated ASIP design-space exploration:
                              search bus width x burst x in-flight x
                              SRAM banks x FU-mix unroll jointly over
                              gf2mm/attention/pqc/pcp and print the
                              cycles-vs-area Pareto frontier (always
                              includes the hand-picked Sec 6.1 configs)
                              --demo         exhaustive trimmed space
                              --space SPEC   axis override, e.g.
                                             width=4|8|16,burst=1..8,
                                             inflight=1|2,banks=1|2,
                                             unroll=1|2
                              --seed N       sampling seed (default 41125)
                              --limit N      max candidates before seeded
                                             sampling kicks in (default 64)
                              --budget SPEC  compile-side budget (same
                                             keys as compile --budget)
                              --area-budget MM2  cap the frontier's SoC
                                             area in mm2
    serve [OPTIONS]           run the paged-KV continuous-batching LLM
                              serving engine over the AOT artifacts:
                              --policy decode-first|prefill-first|fair
                              --batch N      decode batch width (default 4)
                              --cores N      ASIP serving cores on the SoC
                                             (default 1; >1 shards the KV
                                             pool per core with migration,
                                             work stealing and shared-DDR
                                             contention)
                              -n N           ad-hoc request count (default 4)
                              --trace SPEC   deterministic trace replay,
                                             e.g. n=16,seed=7,rate=4,plen=4..12,gen=6..14
                                             (+ burst=B mean burst size,
                                              tail=P heavy-tail prob,
                                              mix=P interactive-SLO prob)
                              --faults SPEC  deterministic fault injection,
                                             e.g. coredown=1@40,dmaerr=0.02,seed=3
                                             (keys: coredown=k@t corestall=k@t..t2
                                              dmaerr=p seed=s surge=x@t..t2;
                                              forces the SoC path, replays
                                              byte-identically for one seed)
    ir-levels                 print the Aquas-IR level summary (Table 1)
    help                      this text
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> aquas::Result<()> {
    match args.first().map(String::as_str) {
        Some("synth") => cmd_synth(&args[1..]),
        Some("compile") => cmd_compile(&args[1..]),
        Some("opt") => cmd_opt(&args[1..]),
        Some("explore") => cmd_explore(&args[1..]),
        Some("bench") => cmd_bench(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("ir-levels") => {
            println!("{}", ir_levels());
            Ok(())
        }
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            Err(aquas::Error::Coordinator("bad usage".into()))
        }
    }
}

fn cmd_synth(args: &[String]) -> aquas::Result<()> {
    let timing_sim =
        args.windows(2).any(|w| w[0] == "--timing" && w[1] == "sim");
    if args.iter().any(|a| a == "--demo") {
        println!("{}", bh::fir7::fig4());
        if timing_sim {
            // Replay both flows' schedules through the event-driven
            // burst-DMA engine and show where (and whether) the closed
            // form the scheduler optimized against disagrees.
            let (smart, naive, itfcs) = bh::fir7::run();
            println!("\n== --timing sim: closed-form vs event-driven burst-DMA replay ==");
            for (label, r) in [("aquas", &smart), ("naive", &naive)] {
                let deltas =
                    aquas::synthesis::scheduling::timing_deltas(&r.schedule, &itfcs)?;
                for (id, closed, sim) in deltas {
                    let delta = sim as i64 - closed as i64;
                    println!(
                        "  {label:<5} {}: closed-form {closed} cyc | simulated {sim} cyc | \
                         delta {delta:+}",
                        itfcs.get(id).name
                    );
                }
            }
            println!(
                "  (uncontended replays match the recurrence exactly; contention — \
                 shared SRAM banks, cross-stream queueing — is where they part)"
            );
        }
        return Ok(());
    }
    eprintln!("synth currently supports: aquas synth --demo fir7 [--timing sim]");
    Ok(())
}

fn all_kernels() -> Vec<aquas::workloads::Kernel> {
    let mut ks = aquas::workloads::table2_kernels();
    ks.extend(aquas::workloads::graphics_kernels());
    ks
}

fn cmd_compile(args: &[String]) -> aquas::Result<()> {
    let name = args.first().ok_or_else(|| {
        aquas::Error::Compiler(
            "usage: aquas compile <kernel> [--variant] [--opt-level 0|2] [--budget SPEC]".into(),
        )
    })?;
    let use_variant = args.iter().any(|a| a == "--variant");
    let opt_level = match args.windows(2).find(|w| w[0] == "--opt-level") {
        None => 0u8,
        Some(w) => match w[1].as_str() {
            "0" => 0,
            "2" => 2,
            other => {
                return Err(aquas::Error::Compiler(format!(
                    "unknown opt level `{other}` (expected 0 or 2)"
                )))
            }
        },
    };
    let ks = all_kernels();
    let k = ks
        .iter()
        .find(|k| k.name == name)
        .ok_or_else(|| aquas::Error::Compiler(format!("unknown kernel `{name}`")))?;
    let func = if use_variant {
        k.variants.first().map(|(_, f)| f.clone()).unwrap_or_else(|| k.software.clone())
    } else {
        k.software.clone()
    };
    let budget = match args.windows(2).find(|w| w[0] == "--budget") {
        None => aquas::compiler::CompileBudget::default(),
        Some(w) => aquas::compiler::CompileBudget::parse(&w[1])?,
    };
    let opts = aquas::compiler::CompileOptions { opt_level, budget };
    let r = aquas::compiler::compile(&func, &[k.isax.clone()], &opts)?;
    println!("kernel: {}", k.name);
    println!("matched: {:?}", r.stats.matched);
    println!(
        "rewrites: {} internal / {} external",
        r.stats.internal_rewrites, r.stats.external_rewrites
    );
    println!(
        "e-nodes: {} initial / {} saturated",
        r.stats.initial_enodes, r.stats.saturated_enodes
    );
    // Surface the saturation outcome that used to be silently dropped:
    // a starved budget degrades the match and says so, instead of
    // pretending the e-graph ran to a fixpoint.
    println!(
        "saturation: {} (node budget {}, match budget {})",
        if r.stats.saturation_complete { "complete" } else { "stopped by budget" },
        if r.stats.node_budget_hit { "hit" } else { "ok" },
        if r.stats.match_budget_hit { "hit" } else { "ok" },
    );
    if opt_level >= 2 {
        println!(
            "mid-end: {} fixpoint rounds{}",
            r.stats.pass_rounds_used,
            if r.stats.pass_budget_hit { " (round budget hit)" } else { "" },
        );
    }
    if r.stats.budget_exhausted() {
        println!("budget exhausted: compile degraded gracefully (IR below is still verified)");
    }
    println!("\nlowered program:\n{}", aquas::ir::printer::print_func(&r.func));
    Ok(())
}

/// `aquas opt --demo`: run the mid-end pipeline on a function packed
/// with one opportunity per pass and show its work — IR before/after,
/// per-pass rewrite counts, and the measured dynamic-op delta (with the
/// optimized run checked for an identical memory image).
fn cmd_opt(args: &[String]) -> aquas::Result<()> {
    use aquas::interface::cache::CacheHint;
    use aquas::ir::{interp, passes, printer, CmpPred, FuncBuilder};
    use aquas::runtime::DType;

    if !args.iter().any(|a| a == "--demo") {
        eprintln!("opt currently supports: aquas opt --demo");
        return Ok(());
    }
    let mut b = FuncBuilder::new("opt_demo");
    let buf = b.global("data", DType::I32, 64, CacheHint::Unknown);
    b.for_range(0, 16, 1, |b, i| {
        let two = b.const_i(2);
        let three = b.const_i(3);
        let six = b.mul(two, three); // sccp: folds to 6
        let base = b.mul(six, two); // sccp: folds to 12, licm hoists it
        let a1 = b.add(base, i);
        let a2 = b.add(base, i); // cse: duplicate address
        let v = b.load(buf, a1);
        let w = b.load(buf, a2); // cse: duplicate load
        let dead = b.mul(v, w); // dce: never used
        let _ = dead;
        let s = b.add(v, w);
        let zero = b.const_i(0);
        let c = b.cmp(CmpPred::Gt, s, zero);
        let heavy = b.mul(s, s); // sink: only the then-arm needs it
        let r = b.if_else(c, |_| vec![heavy], |b| vec![b.const_i(0)]);
        b.store(buf, a1, r[0]);
    });
    let f = b.finish(&[]);

    let (opt, stats) = passes::optimize(&f, passes::OptLevel::O2)?;
    println!("== mid-end pass pipeline demo ==");
    println!("\nbefore:\n{}", printer::print_func(&f));
    println!("after:\n{}", printer::print_func(&opt));
    println!("pipeline: {stats}");

    let run_one = |f: &aquas::ir::Func| -> aquas::Result<(u64, Vec<i32>)> {
        let mut mem = interp::Memory::for_func(f);
        let seed: Vec<i32> = (0..64).map(|i| (i * 13 % 31) - 7).collect();
        mem.write_i32(buf, &seed);
        let mut st = interp::ExecStats::default();
        interp::run_with_stats(f, &[], &mut mem, &mut st)?;
        Ok((st.arith_ops + st.loads + st.stores + st.branches + st.transfers, mem.read_i32(buf)))
    };
    let (d0, m0) = run_one(&f)?;
    let (d1, m1) = run_one(&opt)?;
    println!(
        "dynamic ops: {d0} -> {d1} ({:.1}% reduction) | memory image {}",
        100.0 * (1.0 - d1 as f64 / d0 as f64),
        if m0 == m1 { "identical" } else { "DIVERGED" },
    );
    Ok(())
}

fn cmd_explore(args: &[String]) -> aquas::Result<()> {
    use aquas::compiler::CompileBudget;
    use aquas::dse::{DesignSpace, Explorer};

    let flag = |name: &str| {
        args.windows(2).find(|w| w[0] == name).map(|w| w[1].clone())
    };
    let mut ex = if args.iter().any(|a| a == "--demo") { Explorer::demo() } else { Explorer::full() };
    if let Some(spec) = flag("--space") {
        ex.space = DesignSpace::parse(&spec)?;
    }
    if let Some(s) = flag("--seed") {
        ex.seed = s
            .parse()
            .map_err(|_| aquas::Error::Synthesis(format!("explore: seed `{s}` is not an integer")))?;
    }
    if let Some(s) = flag("--limit") {
        let n: usize = s
            .parse()
            .map_err(|_| aquas::Error::Synthesis(format!("explore: limit `{s}` is not an integer")))?;
        if n == 0 {
            return Err(aquas::Error::Synthesis("explore: limit must be at least 1".into()));
        }
        ex.sample_limit = n;
    }
    if let Some(s) = flag("--budget") {
        ex.budget = CompileBudget::parse(&s)?;
    }
    if let Some(s) = flag("--area-budget") {
        let a: f64 = s.parse().map_err(|_| {
            aquas::Error::Synthesis(format!("explore: area budget `{s}` is not a number"))
        })?;
        if !a.is_finite() || a <= 0.0 {
            return Err(aquas::Error::Synthesis(format!(
                "explore: area budget {a} mm2 is not a positive finite number"
            )));
        }
        ex.area_budget_mm2 = Some(a);
    }

    let r = ex.run()?;
    println!(
        "== aquas explore: {} candidates ({}{} of {} cells), {} infeasible ==",
        r.evaluated.len(),
        if r.sampled { "seeded sample" } else { "exhaustive" },
        if r.sampled { format!(" seed={}", r.seed) } else { String::new() },
        r.space_size,
        r.infeasible.len(),
    );
    for (family, n) in &r.offload_proof {
        println!("e-graph offload proof: {family}: {n} loop(s) offloaded");
    }

    let mut rep = bh::Report::new(
        "cycles x area Pareto frontier (gf2mm + attention + pqc + pcp, joint)",
        vec!["config", "cycles", "area mm2", "freq MHz", "kind"],
    );
    for c in &r.frontier {
        rep.row(vec![
            c.point.key(),
            c.cycles.to_string(),
            format!("{:.4}", c.area_mm2),
            format!("{:.1}", c.freq_mhz),
            "frontier".into(),
        ]);
    }
    for c in &r.baselines {
        let kind = if r.frontier.iter().any(|f| f.point == c.point) {
            "hand-picked (on frontier)"
        } else {
            "hand-picked"
        };
        rep.row(vec![
            c.point.key(),
            c.cycles.to_string(),
            format!("{:.4}", c.area_mm2),
            format!("{:.1}", c.freq_mhz),
            kind.into(),
        ]);
    }
    println!("{}", rep.render());

    for (key, reason) in r.infeasible.iter().take(4) {
        println!("infeasible: {key}: {reason}");
    }
    println!(
        "frontier: {} point(s); mutually non-dominated: {}; covers hand-picked Sec 6.1 configs: {}",
        r.frontier.len(),
        if r.frontier_mutually_nondominated() { "yes" } else { "NO" },
        if r.frontier_covers_baselines() { "yes" } else { "NO" },
    );
    if let (Some(best), Some(default)) = (r.best_cycles_point(), r.baselines.first()) {
        println!(
            "best point {}: {} cycles / {:.4} mm2 ({:.2}x the hand-picked default's cycles at {:+.1}% area)",
            best.point.key(),
            best.cycles,
            best.area_mm2,
            default.cycles as f64 / best.cycles as f64,
            100.0 * (best.area_mm2 - default.area_mm2) / default.area_mm2,
        );
    }
    Ok(())
}

fn cmd_bench(args: &[String]) -> aquas::Result<()> {
    let what = args.first().map(String::as_str).unwrap_or("all");
    let run_one = |name: &str| {
        match name {
            "table2" => println!("{}", bh::table2::report().render()),
            "table3" => println!("{}", bh::table3::report().render()),
            "fig2" => println!("{}", bh::fig2().render()),
            "fig3" => println!("{}", bh::fir7::fig3().render()),
            "fig6" => println!("{}", bh::fig6().render()),
            "fig7" => println!("{}", bh::fig7().render()),
            "fig8" => println!("{}", bh::fig8().render()),
            "egraph" => println!("{}", bh::egraph::report(false).render()),
            "serve" => println!("{}", bh::serve::report(false).render()),
            "interp" => println!("{}", bh::interp::report(false).render()),
            "dma" => println!("{}", bh::dma::report(false).render()),
            "dse" => println!("{}", bh::dse::report(false).render()),
            other => eprintln!("unknown bench `{other}`"),
        };
    };
    if what == "all" {
        for name in ["fig2", "fig3", "table2", "table3", "fig6", "fig7", "fig8"] {
            run_one(name);
        }
    } else {
        run_one(what);
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> aquas::Result<()> {
    let mut policy = SchedulePolicy::DecodeFirst;
    let mut n_requests = 4usize;
    let mut batch = 4usize;
    let mut cores = 1usize;
    let mut trace: Option<String> = None;
    let mut faults: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--policy" => {
                i += 1;
                policy = match args.get(i).map(String::as_str) {
                    Some("prefill-first") => SchedulePolicy::PrefillFirst,
                    Some("fair") => SchedulePolicy::Fair,
                    _ => SchedulePolicy::DecodeFirst,
                };
            }
            "-n" => {
                i += 1;
                n_requests = args.get(i).and_then(|s| s.parse().ok()).unwrap_or(4);
            }
            "--batch" => {
                i += 1;
                batch = args.get(i).and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
            }
            "--cores" => {
                i += 1;
                cores = args.get(i).and_then(|s| s.parse().ok()).unwrap_or(1).max(1);
            }
            "--trace" => {
                i += 1;
                trace = args.get(i).cloned();
            }
            "--faults" => {
                i += 1;
                faults = args.get(i).cloned();
            }
            _ => {}
        }
        i += 1;
    }
    // Fault specs are parsed before touching the runtime so a malformed
    // spec fails fast with a diagnostic rather than after artifact load.
    let fault_plan = match &faults {
        Some(text) => Some(FaultPlan::parse(text)?),
        None => None,
    };
    let rt = Runtime::load("artifacts")?;
    println!("platform: {} | entries: {:?}", rt.platform(), rt.entry_names());
    if cores > 1 || fault_plan.is_some() {
        // Fault injection lives in the SoC coordinator, so `--faults`
        // routes through it even for a single core.
        return cmd_serve_soc(&rt, cores, policy, batch, n_requests, trace.as_deref(), fault_plan);
    }
    let mut coord = Coordinator::new(
        &rt,
        CoordinatorConfig { policy, max_active: batch, ..Default::default() },
    );
    let model = rt.manifest().model.clone();
    if let Some(text) = &trace {
        // Deterministic trace replay: every metric below is on the
        // simulated SoC clock, so two replays print identical bytes.
        let spec = TraceSpec::parse(text)?;
        coord.submit_trace(&spec.generate(model.vocab, model.prefill_len))?;
    } else {
        let mut rng = aquas::util::rng::Rng::new(7);
        for _ in 0..n_requests {
            let len = rng.range(4, model.prefill_len);
            let prompt: Vec<i32> =
                (0..len).map(|_| rng.below(model.vocab as u64) as i32).collect();
            coord.submit(prompt, 8)?;
        }
    }
    let metrics = coord.run_to_completion()?;
    for m in &metrics {
        println!(
            "req {}: prompt {} -> {} tokens | ttft {} us | mean itl {} us | preempted {} | sim speedup {:.2}x",
            m.id,
            m.prompt_len,
            m.generated.len(),
            m.ttft_us,
            if m.itl_us.is_empty() {
                0
            } else {
                m.itl_us.iter().sum::<u128>() / m.itl_us.len() as u128
            },
            m.preemptions,
            m.sim_base_cycles / m.sim_isax_cycles.max(1.0),
        );
    }
    let total_tokens: usize = metrics.iter().map(|m| m.generated.len()).sum();
    let elapsed_s = coord.sim_now_ms() / 1e3;
    let kv = coord.kv_stats();
    println!(
        "total: {} requests, {} tokens in {:.3} sim s -> {:.2} tok/s (batch {batch})",
        metrics.len(),
        total_tokens,
        elapsed_s,
        total_tokens as f64 / elapsed_s.max(1e-12),
    );
    println!(
        "kv: {} blocks x {} slots | peak in use {} | preemptions {} | leak-free {}",
        kv.total_blocks,
        kv.block_slots,
        kv.peak_in_use,
        coord.preemptions(),
        kv.leak_free(),
    );
    Ok(())
}

/// `aquas serve --cores N` (N > 1) or `--faults SPEC`: the same request
/// stream through the N-core SoC — sharded KV pools, async dispatch,
/// cross-core migration and work stealing, with shared-DDR contention on
/// the modelled clock, plus optional deterministic fault injection.
fn cmd_serve_soc(
    rt: &Runtime,
    cores: usize,
    policy: SchedulePolicy,
    batch: usize,
    n_requests: usize,
    trace: Option<&str>,
    faults: Option<FaultPlan>,
) -> aquas::Result<()> {
    let model = rt.manifest().model.clone();
    let reqs: Vec<TraceRequest> = if let Some(text) = trace {
        let spec = TraceSpec::parse(text)?;
        spec.generate_capped(model.vocab, model.prefill_len, model.max_seq)
    } else {
        // Same ad-hoc workload as the single-core path (seed 7), all
        // arriving at t = 0 with the default SLO class.
        let mut rng = aquas::util::rng::Rng::new(7);
        (0..n_requests)
            .map(|_| {
                let len = rng.range(4, model.prefill_len);
                let prompt: Vec<i32> =
                    (0..len).map(|_| rng.below(model.vocab as u64) as i32).collect();
                TraceRequest { arrive_ms: 0.0, prompt, max_new_tokens: 8, slo_factor: 1.0 }
            })
            .collect()
    };
    let plan = faults.unwrap_or_default();
    let chaos = !plan.is_empty();
    let mut soc = SocCoordinator::new(
        rt,
        SocConfig {
            cores,
            per_core: CoordinatorConfig { policy, max_active: batch, ..Default::default() },
            faults: plan,
            ..Default::default()
        },
    );
    soc.submit_trace(&reqs)?;
    let metrics = soc.run_to_completion()?;
    for m in &metrics {
        println!(
            "req {}: prompt {} -> {} tokens | ttft {} us | mean itl {} us | preempted {} | sim speedup {:.2}x",
            m.id,
            m.prompt_len,
            m.generated.len(),
            m.ttft_us,
            if m.itl_us.is_empty() {
                0
            } else {
                m.itl_us.iter().sum::<u128>() / m.itl_us.len() as u128
            },
            m.preemptions,
            m.sim_base_cycles / m.sim_isax_cycles.max(1.0),
        );
    }
    let total_tokens: usize = metrics.iter().map(|m| m.generated.len()).sum();
    let elapsed_s = soc.sim_elapsed_ms() / 1e3;
    let stats = soc.stats();
    println!(
        "total: {} requests, {} tokens in {:.3} sim s -> {:.2} tok/s ({cores} cores x batch {batch})",
        metrics.len(),
        total_tokens,
        elapsed_s,
        total_tokens as f64 / elapsed_s.max(1e-12),
    );
    println!(
        "soc: migrations {} | steals {} | preemptions {} | contention dma cycles {:.0}",
        stats.migrations, stats.steals, stats.preemptions, stats.contention_dma_cycles,
    );
    // Only printed under an active fault plan so the zero-fault serving
    // output stays byte-identical to the pre-chaos CLI.
    if chaos {
        println!(
            "faults: injected {} | dma retries {} | evacuated {} | shed {} | slo violations {}",
            stats.faults_injected,
            stats.dma_retries,
            stats.evacuated_seqs,
            stats.shed_requests,
            stats.slo_violations,
        );
    }
    for (k, kv) in stats.per_core_kv.iter().enumerate() {
        println!(
            "core {k} kv: {} blocks x {} slots | peak in use {} | leak-free {}",
            kv.total_blocks,
            kv.block_slots,
            kv.peak_in_use,
            kv.leak_free(),
        );
    }
    Ok(())
}

fn ir_levels() -> &'static str {
    "\
Table 1 — Aquas-IR abstraction levels
  Functional    | transfer, fetch, read_smem, read_irf | m: transfer size
  Architectural | !memitfc<>, copy #bulk, load #scalar | W,M legality; I,L,E latency; C cache penalty
  Temporal      | copy_issue/copy_wait (after=...)     | I-aware order; hierarchy phase order"
}
