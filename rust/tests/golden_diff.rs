//! Differential testing: every AOT *kernel* entry executed through the
//! Aquas-IR interpreters — **both** the tree-walking oracle (`ir::interp`)
//! and the compiled register-bytecode VM (`ir::vm`) — and the simulated
//! runtime backend (`runtime::sim`, via the public `Runtime::execute`
//! path) on seeded random inputs, asserting bit-equal (integer kernels)
//! or tolerance-equal (float kernels) outputs. Since the `exp` op landed,
//! the attention kernel — softmax included — runs fully in-IR; only the
//! two transformer serving entries (`llm_prefill`/`llm_decode`) remain
//! runtime-only and are pinned by their own cross-path tests — see
//! `every_aot_entry_is_cross_checked` below.
//!
//! The IR spellings live in `aquas::bench_harness::interp` (shared with
//! `cargo bench --bench interp`, which replays them through both engines
//! for the `speedup_vs_legacy` numbers); the runtime implementations come
//! from the Pallas golden models (`python/compile/kernels/ref.py`). The
//! three implementations were written independently, so agreement here
//! pins the semantic contract between the compiler stack's ground truth
//! and the serving runtime. Each IR kernel is built at the *manifest*
//! shapes, so the runtime call goes through the full typechecked entry
//! path.
//!
//! The interpreters compute in f64 and the runtime in f32, so float
//! comparisons use a relative tolerance; integer kernels must match
//! exactly. The two IR engines must agree **bit-exactly** (outputs,
//! memory image, and `ExecStats`) — `run_both` asserts that on every
//! kernel in this file.

use aquas::bench_harness::interp as irk;
use aquas::ir::interp::{run_with_stats, ExecStats, Memory};
use aquas::ir::{vm, Func};
use aquas::runtime::{Runtime, Tensor};
use aquas::util::rng::Rng;
use aquas::workloads::llm::ir_causal_attention;
use aquas::workloads::Kernel;

fn runtime() -> Runtime {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::load(&dir).expect("runtime load (simulated fallback) cannot fail")
}

fn normals(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn bits(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(2) as i32).collect()
}

#[track_caller]
fn assert_close(name: &str, got: &[f32], want: &[f32], rel: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = rel * w.abs().max(1.0);
        assert!(
            (g - w).abs() <= tol,
            "{name}[{i}]: interp {g} vs sim {w} (tol {tol})"
        );
    }
}

/// Run `f` through the tree-walker AND the bytecode VM on identically
/// initialized memories; assert the two engines agree bit-exactly on
/// stats and the full memory image, then hand back the image for the
/// runtime comparison.
fn run_both(f: &Func, init: impl FnOnce(&mut Memory)) -> Memory {
    let mut m1 = Memory::for_func(f);
    init(&mut m1);
    let mut m2 = m1.clone();
    let mut s1 = ExecStats::default();
    let mut s2 = ExecStats::default();
    let o1 = run_with_stats(f, &[], &mut m1, &mut s1)
        .unwrap_or_else(|e| panic!("{}: tree-walker failed: {e}", f.name));
    let o2 = vm::compile(f)
        .unwrap_or_else(|e| panic!("{}: vm compile failed: {e}", f.name))
        .run_with_stats(&[], &mut m2, &mut s2)
        .unwrap_or_else(|e| panic!("{}: vm failed: {e}", f.name));
    assert_eq!(o1, o2, "{}: engine outputs diverge", f.name);
    assert_eq!(s1, s2, "{}: engine stats diverge", f.name);
    irk::memories_equal(f, &m1, &m2).unwrap_or_else(|e| panic!("{e}"));
    m1
}

// ---------------------------------------------------------------------------
// gf2mm — [64,64] x [64,64] over GF(2); bit-equal
// ---------------------------------------------------------------------------

#[test]
fn diff_gf2mm_bit_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_60);
    let a = bits(&mut rng, 64 * 64);
    let e = bits(&mut rng, 64 * 64);

    let f = irk::ir_gf2mm(64);
    let mem = run_both(&f, |m| {
        m.write_i32(Kernel::buf(&f, "a"), &a);
        m.write_i32(Kernel::buf(&f, "b"), &e);
    });
    let ir_out = mem.read_i32(Kernel::buf(&f, "s"));

    let sim = rt
        .execute(
            "gf2mm",
            &[Tensor::i32(a, &[64, 64]).unwrap(), Tensor::i32(e, &[64, 64]).unwrap()],
        )
        .unwrap();
    assert_eq!(ir_out.as_slice(), sim[0].as_i32().unwrap(), "gf2mm bitstreams diverge");
}

// ---------------------------------------------------------------------------
// vdecomp — [16] words -> [512] bits; bit-equal (shift/mask spelling)
// ---------------------------------------------------------------------------

#[test]
fn diff_vdecomp_bit_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_DE);
    let words: Vec<i32> = (0..16).map(|_| rng.next_u64() as i32).collect();

    let f = irk::ir_vdecomp(16);
    let mem = run_both(&f, |m| {
        m.write_i32(Kernel::buf(&f, "e"), &words);
    });
    let ir_out = mem.read_i32(Kernel::buf(&f, "out"));

    let sim = rt.execute("vdecomp", &[Tensor::i32(words, &[16]).unwrap()]).unwrap();
    assert_eq!(ir_out.as_slice(), sim[0].as_i32().unwrap(), "vdecomp bitstreams diverge");
}

// ---------------------------------------------------------------------------
// vdist3 — [256,3]^2 -> [256]
// ---------------------------------------------------------------------------

#[test]
fn diff_vdist3_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_D3);
    let p = normals(&mut rng, 256 * 3);
    let q = normals(&mut rng, 256 * 3);

    let f = irk::ir_vdist3(256);
    let mem = run_both(&f, |m| {
        m.write_f32(Kernel::buf(&f, "p"), &p);
        m.write_f32(Kernel::buf(&f, "q"), &q);
    });
    let ir_out = mem.read_f32(Kernel::buf(&f, "d"));

    let sim = rt
        .execute(
            "vdist3",
            &[Tensor::f32(p, &[256, 3]).unwrap(), Tensor::f32(q, &[256, 3]).unwrap()],
        )
        .unwrap();
    assert_close("vdist3", &ir_out, sim[0].as_f32().unwrap(), 1e-4);
}

// ---------------------------------------------------------------------------
// mcov — [256,3]^2 -> [3,3] cross-covariance of *centered* points
// ---------------------------------------------------------------------------

#[test]
fn diff_mcov_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_C0);
    let p = normals(&mut rng, 256 * 3);
    let q = normals(&mut rng, 256 * 3);

    let f = irk::ir_mcov_centered(256);
    let mem = run_both(&f, |m| {
        m.write_f32(Kernel::buf(&f, "p"), &p);
        m.write_f32(Kernel::buf(&f, "q"), &q);
    });
    let ir_out = mem.read_f32(Kernel::buf(&f, "cov"));

    let sim = rt
        .execute(
            "mcov",
            &[Tensor::f32(p, &[256, 3]).unwrap(), Tensor::f32(q, &[256, 3]).unwrap()],
        )
        .unwrap();
    // f64 interpreter accumulation vs f32 backend over 256 points.
    assert_close("mcov", &ir_out, sim[0].as_f32().unwrap(), 5e-3);
}

// ---------------------------------------------------------------------------
// vfsmax — [256] -> max + argmax
// ---------------------------------------------------------------------------

#[test]
fn diff_vfsmax_exact() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_F5);
    let xs = normals(&mut rng, 256);

    let f = irk::ir_vfsmax(256);
    let mem = run_both(&f, |m| {
        m.write_f32(Kernel::buf(&f, "x"), &xs);
        // The IR loop refines from x[0] (matches the sim's best = 0 seed).
        m.write_f32(Kernel::buf(&f, "mx"), &[xs[0]]);
    });
    let ir_max = mem.read_f32(Kernel::buf(&f, "mx"))[0];
    let ir_arg = mem.read_i32(Kernel::buf(&f, "am"))[0];

    let sim = rt.execute("vfsmax", &[Tensor::f32(xs, &[256]).unwrap()]).unwrap();
    let sim_max = sim[0].as_f32().unwrap()[0];
    let sim_arg = sim[1].as_i32().unwrap()[0];
    // Max/argmax involve no arithmetic: both paths must agree exactly
    // (strict-> comparisons, first-wins ties on both sides).
    assert_eq!(ir_max, sim_max, "vfsmax max diverges");
    assert_eq!(ir_arg, sim_arg, "vfsmax argmax diverges");
}

// ---------------------------------------------------------------------------
// vmadot — [64,64] · [64] -> [64]
// ---------------------------------------------------------------------------

#[test]
fn diff_vmadot_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_3A);
    let m = normals(&mut rng, 64 * 64);
    let v = normals(&mut rng, 64);

    let f = irk::ir_vmadot(64, 64);
    let mem = run_both(&f, |mm| {
        mm.write_f32(Kernel::buf(&f, "m"), &m);
        mm.write_f32(Kernel::buf(&f, "v"), &v);
    });
    let ir_out = mem.read_f32(Kernel::buf(&f, "y"));

    let sim = rt
        .execute(
            "vmadot",
            &[Tensor::f32(m, &[64, 64]).unwrap(), Tensor::f32(v, &[64]).unwrap()],
        )
        .unwrap();
    assert_close("vmadot", &ir_out, sim[0].as_f32().unwrap(), 1e-3);
}

// ---------------------------------------------------------------------------
// phong — [256,3]^3 unit vectors -> [256]
// ---------------------------------------------------------------------------

fn unit_vectors(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut data = Vec::with_capacity(n * 3);
    for _ in 0..n {
        let (x, y, z) = (rng.normal(), rng.normal(), rng.normal());
        let len = (x * x + y * y + z * z).sqrt().max(1e-9);
        data.extend([(x / len) as f32, (y / len) as f32, (z / len) as f32]);
    }
    data
}

#[test]
fn diff_phong_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_40);
    let nrm = unit_vectors(&mut rng, 256);
    let lgt = unit_vectors(&mut rng, 256);
    let view = unit_vectors(&mut rng, 256);

    let f = irk::ir_phong(256);
    let mem = run_both(&f, |m| {
        m.write_f32(Kernel::buf(&f, "nrm"), &nrm);
        m.write_f32(Kernel::buf(&f, "lgt"), &lgt);
        m.write_f32(Kernel::buf(&f, "view"), &view);
    });
    let ir_out = mem.read_f32(Kernel::buf(&f, "inten"));

    let sim = rt
        .execute(
            "phong",
            &[
                Tensor::f32(nrm, &[256, 3]).unwrap(),
                Tensor::f32(lgt, &[256, 3]).unwrap(),
                Tensor::f32(view, &[256, 3]).unwrap(),
            ],
        )
        .unwrap();
    assert_close("phong", &ir_out, sim[0].as_f32().unwrap(), 1e-3);
}

// ---------------------------------------------------------------------------
// vrgb2yuv — [256,3] -> [256,3]
// ---------------------------------------------------------------------------

#[test]
fn diff_vrgb2yuv_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_59);
    let rgb: Vec<f32> = (0..256 * 3).map(|_| rng.f32()).collect();

    let f = irk::ir_vrgb2yuv(256);
    let mem = run_both(&f, |m| {
        m.write_f32(Kernel::buf(&f, "rgb"), &rgb);
    });
    let ir_out = mem.read_f32(Kernel::buf(&f, "yuv"));

    let sim = rt.execute("vrgb2yuv", &[Tensor::f32(rgb, &[256, 3]).unwrap()]).unwrap();
    assert_close("vrgb2yuv", &ir_out, sim[0].as_f32().unwrap(), 1e-4);
}

// ---------------------------------------------------------------------------
// vmvar — [64,16] -> ([64] mean, [64] var)
// ---------------------------------------------------------------------------

#[test]
fn diff_vmvar_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_3B);
    let xs = normals(&mut rng, 64 * 16);

    let f = irk::ir_vmvar(64, 16);
    let mem = run_both(&f, |m| {
        m.write_f32(Kernel::buf(&f, "x"), &xs);
    });
    let ir_mean = mem.read_f32(Kernel::buf(&f, "mean"));
    let ir_var = mem.read_f32(Kernel::buf(&f, "var"));

    let sim = rt.execute("vmvar", &[Tensor::f32(xs, &[64, 16]).unwrap()]).unwrap();
    assert_close("vmvar mean", &ir_mean, sim[0].as_f32().unwrap(), 1e-3);
    assert_close("vmvar var", &ir_var, sim[1].as_f32().unwrap(), 1e-3);
}

// ---------------------------------------------------------------------------
// attention — [1,4,64,16] causal MHA, softmax fully in-IR
// ---------------------------------------------------------------------------
//
// Historically the IR had no exp op, so the softmax was staged host-side
// between two interpreted GEMM stages. With `exp`, the whole kernel —
// scaled causal scores, two-pass stable softmax, probability-weighted
// value sum — is one Aquas-IR function (`workloads::llm::
// ir_causal_attention`), interpreted in f64 and compared against the
// runtime's one-shot f32 attention entry.

const AH: i64 = 4; // heads
const AT: i64 = 64; // sequence
const AD: i64 = 16; // head dim

#[test]
fn diff_attention_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_A7);
    let n = (AH * AT * AD) as usize;
    let q = normals(&mut rng, n);
    let k = normals(&mut rng, n);
    let v = normals(&mut rng, n);

    let f = ir_causal_attention(AH, AT, AD);
    let mem = run_both(&f, |m| {
        m.write_f32(Kernel::buf(&f, "q"), &q);
        m.write_f32(Kernel::buf(&f, "k"), &k);
        m.write_f32(Kernel::buf(&f, "v"), &v);
    });
    let ir_out = mem.read_f32(Kernel::buf(&f, "o"));

    // Runtime path: the one-shot causal MHA entry.
    let shape = [1usize, AH as usize, AT as usize, AD as usize];
    let sim = rt
        .execute(
            "attention",
            &[
                Tensor::f32(q, &shape).unwrap(),
                Tensor::f32(k, &shape).unwrap(),
                Tensor::f32(v, &shape).unwrap(),
            ],
        )
        .unwrap();
    assert_close("attention", &ir_out, sim[0].as_f32().unwrap(), 2e-3);
}

// ---------------------------------------------------------------------------
// Mid-end: the pass pipeline on every kernel at manifest shapes
// ---------------------------------------------------------------------------

/// Every AOT kernel, run through the full `ir::passes` pipeline, must
/// leave a bit-identical final memory image — on both engines (via
/// `run_both` on each side). This is the golden-path counterpart of the
/// fuzz sweep in `tests/vm_diff.rs`.
#[test]
fn optimized_kernels_stay_bit_identical_on_both_engines() {
    use aquas::ir::passes::{optimize, OptLevel};
    for (name, f) in irk::aot_cases() {
        let (opt, _) =
            optimize(&f, OptLevel::O2).unwrap_or_else(|e| panic!("{name}: pipeline failed: {e}"));
        let m_un = run_both(&f, |m| irk::seed_memory(&f, m, 0x0457));
        let m_op = run_both(&opt, |m| irk::seed_memory(&opt, m, 0x0457));
        irk::memories_equal(&f, &m_un, &m_op)
            .unwrap_or_else(|e| panic!("{name}: optimized memory image diverges: {e}"));
    }
}

/// The two index-math-heavy kernels must get strictly cheaper — at least
/// the 20% dynamic-op floor the bench `--check` gate enforces.
#[test]
fn pipeline_cuts_attention_and_gf2mm_dynamic_ops() {
    use aquas::ir::passes::{optimize, OptLevel};
    for (name, f) in irk::aot_cases() {
        if name != "attention" && name != "gf2mm" {
            continue;
        }
        let (opt, _) = optimize(&f, OptLevel::O2).unwrap();
        let d0 = irk::dynamic_ops(&f, 0x0457).unwrap();
        let d1 = irk::dynamic_ops(&opt, 0x0457).unwrap();
        assert!(d1 < d0, "{name}: dynamic ops did not decrease ({d0} -> {d1})");
        let reduction = 1.0 - d1 as f64 / d0 as f64;
        assert!(
            reduction >= 0.20,
            "{name}: dynamic-op reduction {:.1}% is below the 20% floor ({d0} -> {d1})",
            reduction * 100.0
        );
    }
}

// ---------------------------------------------------------------------------
// Sweep: every manifest entry is accounted for (fail loudly if a future
// entry lands without a cross-check).
// ---------------------------------------------------------------------------

#[test]
fn every_aot_entry_is_cross_checked() {
    let rt = runtime();
    // Kernel entries with an interp-vs-vm-vs-sim differential test in
    // this file.
    let diffed = [
        "attention", "gf2mm", "mcov", "phong", "vdecomp", "vdist3", "vfsmax", "vmadot",
        "vmvar", "vrgb2yuv",
    ];
    // The transformer serving entries stay runtime-only (a full Llama
    // block in the IR needs rsqrt-normalization and weight streaming the
    // IR deliberately does not model); they are pinned by their own
    // cross-path tests: teacher-forcing prefill/decode consistency and
    // causality in runtime/sim.rs, the host-side softmax(QKᵀ)V oracle
    // and the bitwise batched-vs-entry decode comparison in
    // runtime_integration.rs.
    let serving = ["llm_decode", "llm_prefill"];
    for name in rt.entry_names() {
        assert!(
            diffed.contains(&name.as_str()) || serving.contains(&name.as_str()),
            "entry `{name}` has no differential test in golden_diff.rs and is not a \
             known serving entry — add one"
        );
    }
}
