//! Differential testing: every AOT *kernel* entry executed through
//! **both** the Aquas-IR reference interpreter (`ir::interp`) and the
//! simulated runtime backend (`runtime::sim`, via the public
//! `Runtime::execute` path) on seeded random inputs, asserting bit-equal
//! (integer kernels) or tolerance-equal (float kernels) outputs. The two
//! transformer serving entries (`llm_prefill`/`llm_decode`) are not
//! expressible in the IR (no exp op) and are pinned by their own
//! cross-path tests — see `every_aot_entry_is_cross_checked` below.
//!
//! The two implementations were written independently — the IR kernels
//! from the paper's §6 case-study loops, the runtime from the Pallas
//! golden models (`python/compile/kernels/ref.py`) — so agreement here
//! pins the semantic contract between the compiler stack's ground truth
//! and the serving runtime. Each IR kernel below is built at the
//! *manifest* shapes (the workload modules use smaller study shapes), so
//! the runtime call goes through the full typechecked entry path.
//!
//! The interpreter computes in f64 and the runtime in f32, so float
//! comparisons use a relative tolerance; integer kernels must match
//! exactly.

use aquas::interface::cache::CacheHint;
use aquas::ir::builder::FuncBuilder;
use aquas::ir::interp::{run as interp, Memory};
use aquas::ir::Func;
use aquas::runtime::{DType, Runtime, Tensor};
use aquas::util::rng::Rng;
use aquas::workloads::graphics::{KA, KD, KS, RGB2YUV, SHININESS};
use aquas::workloads::Kernel;

fn runtime() -> Runtime {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::load(&dir).expect("runtime load (simulated fallback) cannot fail")
}

fn normals(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn bits(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(2) as i32).collect()
}

#[track_caller]
fn assert_close(name: &str, got: &[f32], want: &[f32], rel: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = rel * w.abs().max(1.0);
        assert!(
            (g - w).abs() <= tol,
            "{name}[{i}]: interp {g} vs sim {w} (tol {tol})"
        );
    }
}

// ---------------------------------------------------------------------------
// gf2mm — [64,64] x [64,64] over GF(2); bit-equal
// ---------------------------------------------------------------------------

fn ir_gf2mm(n: i64) -> Func {
    let mut b = FuncBuilder::new("gf2mm_diff");
    let a = b.global("a", DType::I32, (n * n) as usize, CacheHint::Warm);
    let bm = b.global("b", DType::I32, (n * n) as usize, CacheHint::Warm);
    let s = b.global("s", DType::I32, (n * n) as usize, CacheHint::Warm);
    b.for_range(0, n, 1, |b, r| {
        b.for_range(0, n, 1, |b, c| {
            b.for_range(0, n, 1, |b, k| {
                let nn = b.const_i(n);
                let rk = b.mul(r, nn);
                let aidx = b.add(rk, k);
                let av = b.load(a, aidx);
                let kn = b.mul(k, nn);
                let bidx = b.add(kn, c);
                let bv = b.load(bm, bidx);
                let prod = b.and(av, bv);
                let rc = b.mul(r, nn);
                let sidx = b.add(rc, c);
                let sv = b.load(s, sidx);
                let acc = b.xor(sv, prod);
                b.store(s, sidx, acc);
            });
        });
    });
    b.finish(&[])
}

#[test]
fn diff_gf2mm_bit_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_60);
    let a = bits(&mut rng, 64 * 64);
    let e = bits(&mut rng, 64 * 64);

    let f = ir_gf2mm(64);
    let mut mem = Memory::for_func(&f);
    mem.write_i32(Kernel::buf(&f, "a"), &a);
    mem.write_i32(Kernel::buf(&f, "b"), &e);
    interp(&f, &[], &mut mem).unwrap();
    let ir_out = mem.read_i32(Kernel::buf(&f, "s"));

    let sim = rt
        .execute(
            "gf2mm",
            &[Tensor::i32(a, &[64, 64]).unwrap(), Tensor::i32(e, &[64, 64]).unwrap()],
        )
        .unwrap();
    assert_eq!(ir_out.as_slice(), sim[0].as_i32().unwrap(), "gf2mm bitstreams diverge");
}

// ---------------------------------------------------------------------------
// vdecomp — [16] words -> [512] bits; bit-equal (shift/mask spelling)
// ---------------------------------------------------------------------------

fn ir_vdecomp(nwords: i64) -> Func {
    let nbits = nwords * 32;
    let mut b = FuncBuilder::new("vdecomp_diff");
    let e = b.global("e", DType::I32, nwords as usize, CacheHint::Warm);
    let out = b.global("out", DType::I32, nbits as usize, CacheHint::Warm);
    b.for_range(0, nbits, 1, |b, i| {
        let five = b.const_i(5);
        let word_idx = b.shr(i, five);
        let w = b.load(e, word_idx);
        let mask31 = b.const_i(31);
        let sh = b.and(i, mask31);
        let shifted = b.shr(w, sh);
        let one = b.const_i(1);
        let bit = b.and(shifted, one);
        b.store(out, i, bit);
    });
    b.finish(&[])
}

#[test]
fn diff_vdecomp_bit_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_DE);
    let words: Vec<i32> = (0..16).map(|_| rng.next_u64() as i32).collect();

    let f = ir_vdecomp(16);
    let mut mem = Memory::for_func(&f);
    mem.write_i32(Kernel::buf(&f, "e"), &words);
    interp(&f, &[], &mut mem).unwrap();
    let ir_out = mem.read_i32(Kernel::buf(&f, "out"));

    let sim = rt.execute("vdecomp", &[Tensor::i32(words, &[16]).unwrap()]).unwrap();
    assert_eq!(ir_out.as_slice(), sim[0].as_i32().unwrap(), "vdecomp bitstreams diverge");
}

// ---------------------------------------------------------------------------
// vdist3 — [256,3]^2 -> [256]
// ---------------------------------------------------------------------------

fn ir_vdist3(n: i64) -> Func {
    let mut b = FuncBuilder::new("vdist3_diff");
    let p = b.global("p", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let q = b.global("q", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let d = b.global("d", DType::F32, n as usize, CacheHint::Warm);
    b.for_range(0, n, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        let mut acc = b.const_f(0.0);
        for dim in 0..3 {
            let off = b.const_i(dim);
            let idx = b.add(base, off);
            let pv = b.load(p, idx);
            let qv = b.load(q, idx);
            let diff = b.sub(pv, qv);
            let sq = b.mul(diff, diff);
            acc = b.add(acc, sq);
        }
        b.store(d, i, acc);
    });
    b.finish(&[])
}

#[test]
fn diff_vdist3_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_D3);
    let p = normals(&mut rng, 256 * 3);
    let q = normals(&mut rng, 256 * 3);

    let f = ir_vdist3(256);
    let mut mem = Memory::for_func(&f);
    mem.write_f32(Kernel::buf(&f, "p"), &p);
    mem.write_f32(Kernel::buf(&f, "q"), &q);
    interp(&f, &[], &mut mem).unwrap();
    let ir_out = mem.read_f32(Kernel::buf(&f, "d"));

    let sim = rt
        .execute(
            "vdist3",
            &[Tensor::f32(p, &[256, 3]).unwrap(), Tensor::f32(q, &[256, 3]).unwrap()],
        )
        .unwrap();
    assert_close("vdist3", &ir_out, sim[0].as_f32().unwrap(), 1e-4);
}

// ---------------------------------------------------------------------------
// mcov — [256,3]^2 -> [3,3] cross-covariance of *centered* points
// ---------------------------------------------------------------------------

fn ir_mcov_centered(n: i64) -> Func {
    let mut b = FuncBuilder::new("mcov_diff");
    let p = b.global("p", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let q = b.global("q", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let pm = b.global("pm", DType::F32, 3, CacheHint::Warm);
    let qm = b.global("qm", DType::F32, 3, CacheHint::Warm);
    let cov = b.global("cov", DType::F32, 9, CacheHint::Warm);
    // Column sums.
    b.for_range(0, n, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        for d in 0..3 {
            let off = b.const_i(d);
            let idx = b.add(base, off);
            let pv = b.load(p, idx);
            let ps = b.load(pm, off);
            let ps2 = b.add(ps, pv);
            b.store(pm, off, ps2);
            let qv = b.load(q, idx);
            let qs = b.load(qm, off);
            let qs2 = b.add(qs, qv);
            b.store(qm, off, qs2);
        }
    });
    // Sums -> means.
    b.for_range(0, 3, 1, |b, d| {
        let nf = b.const_f(n as f64);
        let ps = b.load(pm, d);
        let pmean = b.div(ps, nf);
        b.store(pm, d, pmean);
        let qs = b.load(qm, d);
        let qmean = b.div(qs, nf);
        b.store(qm, d, qmean);
    });
    // Centered cross-covariance.
    b.for_range(0, n, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        b.for_range(0, 3, 1, |b, r| {
            b.for_range(0, 3, 1, |b, c| {
                let pr = b.add(base, r);
                let pv = b.load(p, pr);
                let pmv = b.load(pm, r);
                let pc = b.sub(pv, pmv);
                let qc_idx = b.add(base, c);
                let qv = b.load(q, qc_idx);
                let qmv = b.load(qm, c);
                let qc = b.sub(qv, qmv);
                let prod = b.mul(pc, qc);
                let three2 = b.const_i(3);
                let rr = b.mul(r, three2);
                let cidx = b.add(rr, c);
                let old = b.load(cov, cidx);
                let acc = b.add(old, prod);
                b.store(cov, cidx, acc);
            });
        });
    });
    b.finish(&[])
}

#[test]
fn diff_mcov_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_C0);
    let p = normals(&mut rng, 256 * 3);
    let q = normals(&mut rng, 256 * 3);

    let f = ir_mcov_centered(256);
    let mut mem = Memory::for_func(&f);
    mem.write_f32(Kernel::buf(&f, "p"), &p);
    mem.write_f32(Kernel::buf(&f, "q"), &q);
    interp(&f, &[], &mut mem).unwrap();
    let ir_out = mem.read_f32(Kernel::buf(&f, "cov"));

    let sim = rt
        .execute(
            "mcov",
            &[Tensor::f32(p, &[256, 3]).unwrap(), Tensor::f32(q, &[256, 3]).unwrap()],
        )
        .unwrap();
    // f64 interpreter accumulation vs f32 backend over 256 points.
    assert_close("mcov", &ir_out, sim[0].as_f32().unwrap(), 5e-3);
}

// ---------------------------------------------------------------------------
// vfsmax — [256] -> max + argmax
// ---------------------------------------------------------------------------

fn ir_vfsmax(n: i64) -> Func {
    let mut b = FuncBuilder::new("vfsmax_diff");
    let x = b.global("x", DType::F32, n as usize, CacheHint::Warm);
    let mx = b.global("mx", DType::F32, 1, CacheHint::Warm);
    let am = b.global("am", DType::I32, 1, CacheHint::Warm);
    b.for_range(0, n, 1, |b, i| {
        let v = b.load(x, i);
        let zero = b.const_i(0);
        let cur = b.load(mx, zero);
        let better = b.cmp(aquas::ir::ops::CmpPred::Gt, v, cur);
        let newmax = b.select(better, v, cur);
        b.store(mx, zero, newmax);
        let curi = b.load(am, zero);
        let newi = b.select(better, i, curi);
        b.store(am, zero, newi);
    });
    b.finish(&[])
}

#[test]
fn diff_vfsmax_exact() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_F5);
    let xs = normals(&mut rng, 256);

    let f = ir_vfsmax(256);
    let mut mem = Memory::for_func(&f);
    mem.write_f32(Kernel::buf(&f, "x"), &xs);
    // The IR loop refines from x[0] (matches the sim's best = 0 seed).
    mem.write_f32(Kernel::buf(&f, "mx"), &[xs[0]]);
    interp(&f, &[], &mut mem).unwrap();
    let ir_max = mem.read_f32(Kernel::buf(&f, "mx"))[0];
    let ir_arg = mem.read_i32(Kernel::buf(&f, "am"))[0];

    let sim = rt.execute("vfsmax", &[Tensor::f32(xs, &[256]).unwrap()]).unwrap();
    let sim_max = sim[0].as_f32().unwrap()[0];
    let sim_arg = sim[1].as_i32().unwrap()[0];
    // Max/argmax involve no arithmetic: both paths must agree exactly
    // (strict-> comparisons, first-wins ties on both sides).
    assert_eq!(ir_max, sim_max, "vfsmax max diverges");
    assert_eq!(ir_arg, sim_arg, "vfsmax argmax diverges");
}

// ---------------------------------------------------------------------------
// vmadot — [64,64] · [64] -> [64]
// ---------------------------------------------------------------------------

fn ir_vmadot(rows: i64, cols: i64) -> Func {
    let mut b = FuncBuilder::new("vmadot_diff");
    let m = b.global("m", DType::F32, (rows * cols) as usize, CacheHint::Warm);
    let v = b.global("v", DType::F32, cols as usize, CacheHint::Warm);
    let y = b.global("y", DType::F32, rows as usize, CacheHint::Warm);
    b.for_range(0, rows, 1, |b, r| {
        b.for_range(0, cols, 1, |b, c| {
            let cc = b.const_i(cols);
            let rb = b.mul(r, cc);
            let midx = b.add(rb, c);
            let mv = b.load(m, midx);
            let vv = b.load(v, c);
            let prod = b.mul(mv, vv);
            let old = b.load(y, r);
            let acc = b.add(old, prod);
            b.store(y, r, acc);
        });
    });
    b.finish(&[])
}

#[test]
fn diff_vmadot_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_3A);
    let m = normals(&mut rng, 64 * 64);
    let v = normals(&mut rng, 64);

    let f = ir_vmadot(64, 64);
    let mut mem = Memory::for_func(&f);
    mem.write_f32(Kernel::buf(&f, "m"), &m);
    mem.write_f32(Kernel::buf(&f, "v"), &v);
    interp(&f, &[], &mut mem).unwrap();
    let ir_out = mem.read_f32(Kernel::buf(&f, "y"));

    let sim = rt
        .execute(
            "vmadot",
            &[Tensor::f32(m, &[64, 64]).unwrap(), Tensor::f32(v, &[64]).unwrap()],
        )
        .unwrap();
    assert_close("vmadot", &ir_out, sim[0].as_f32().unwrap(), 1e-3);
}

// ---------------------------------------------------------------------------
// phong — [256,3]^3 unit vectors -> [256]
// ---------------------------------------------------------------------------

fn ir_phong(n: i64) -> Func {
    let mut b = FuncBuilder::new("phong_diff");
    let nrm = b.global("nrm", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let lgt = b.global("lgt", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let view = b.global("view", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let out = b.global("inten", DType::F32, n as usize, CacheHint::Warm);
    b.for_range(0, n, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        let mut nv = [None; 3];
        let mut lv = [None; 3];
        let mut vv = [None; 3];
        for d in 0..3usize {
            let off = b.const_i(d as i64);
            let idx = b.add(base, off);
            nv[d] = Some(b.load(nrm, idx));
            lv[d] = Some(b.load(lgt, idx));
            vv[d] = Some(b.load(view, idx));
        }
        let mut ndotl = b.const_f(0.0);
        for d in 0..3 {
            let p = b.mul(nv[d].unwrap(), lv[d].unwrap());
            ndotl = b.add(ndotl, p);
        }
        let zero_f = b.const_f(0.0);
        let ndotl = b.max(ndotl, zero_f);
        let two = b.const_f(2.0);
        let scale = b.mul(two, ndotl);
        let mut rdotv = b.const_f(0.0);
        for d in 0..3 {
            let rn = b.mul(scale, nv[d].unwrap());
            let refl = b.sub(rn, lv[d].unwrap());
            let p = b.mul(refl, vv[d].unwrap());
            rdotv = b.add(rdotv, p);
        }
        let zero_f2 = b.const_f(0.0);
        let rdotv = b.max(rdotv, zero_f2);
        let spec_pow = b.powi(rdotv, SHININESS);
        let gate = b.cmp(aquas::ir::ops::CmpPred::Gt, ndotl, zero_f2);
        let zero_f3 = b.const_f(0.0);
        let spec = b.select(gate, spec_pow, zero_f3);
        let ka = b.const_f(KA);
        let kd = b.const_f(KD);
        let ks = b.const_f(KS);
        let diff = b.mul(kd, ndotl);
        let sp = b.mul(ks, spec);
        let partial = b.add(ka, diff);
        let inten = b.add(partial, sp);
        b.store(out, i, inten);
    });
    b.finish(&[])
}

fn unit_vectors(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut data = Vec::with_capacity(n * 3);
    for _ in 0..n {
        let (x, y, z) = (rng.normal(), rng.normal(), rng.normal());
        let len = (x * x + y * y + z * z).sqrt().max(1e-9);
        data.extend([(x / len) as f32, (y / len) as f32, (z / len) as f32]);
    }
    data
}

#[test]
fn diff_phong_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_40);
    let nrm = unit_vectors(&mut rng, 256);
    let lgt = unit_vectors(&mut rng, 256);
    let view = unit_vectors(&mut rng, 256);

    let f = ir_phong(256);
    let mut mem = Memory::for_func(&f);
    mem.write_f32(Kernel::buf(&f, "nrm"), &nrm);
    mem.write_f32(Kernel::buf(&f, "lgt"), &lgt);
    mem.write_f32(Kernel::buf(&f, "view"), &view);
    interp(&f, &[], &mut mem).unwrap();
    let ir_out = mem.read_f32(Kernel::buf(&f, "inten"));

    let sim = rt
        .execute(
            "phong",
            &[
                Tensor::f32(nrm, &[256, 3]).unwrap(),
                Tensor::f32(lgt, &[256, 3]).unwrap(),
                Tensor::f32(view, &[256, 3]).unwrap(),
            ],
        )
        .unwrap();
    assert_close("phong", &ir_out, sim[0].as_f32().unwrap(), 1e-3);
}

// ---------------------------------------------------------------------------
// vrgb2yuv — [256,3] -> [256,3]
// ---------------------------------------------------------------------------

fn ir_vrgb2yuv(n: i64) -> Func {
    let mut b = FuncBuilder::new("vrgb2yuv_diff");
    let rgb = b.global("rgb", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let yuv = b.global("yuv", DType::F32, (n * 3) as usize, CacheHint::Warm);
    b.for_range(0, n, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        for (row, coeffs) in RGB2YUV.iter().enumerate() {
            let mut acc = b.const_f(0.0);
            for (c, &coeff) in coeffs.iter().enumerate() {
                let off = b.const_i(c as i64);
                let idx = b.add(base, off);
                let v = b.load(rgb, idx);
                let k = b.const_f(coeff);
                let p = b.mul(v, k);
                acc = b.add(acc, p);
            }
            let roff = b.const_i(row as i64);
            let oidx = b.add(base, roff);
            b.store(yuv, oidx, acc);
        }
    });
    b.finish(&[])
}

#[test]
fn diff_vrgb2yuv_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_59);
    let rgb: Vec<f32> = (0..256 * 3).map(|_| rng.f32()).collect();

    let f = ir_vrgb2yuv(256);
    let mut mem = Memory::for_func(&f);
    mem.write_f32(Kernel::buf(&f, "rgb"), &rgb);
    interp(&f, &[], &mut mem).unwrap();
    let ir_out = mem.read_f32(Kernel::buf(&f, "yuv"));

    let sim = rt.execute("vrgb2yuv", &[Tensor::f32(rgb, &[256, 3]).unwrap()]).unwrap();
    assert_close("vrgb2yuv", &ir_out, sim[0].as_f32().unwrap(), 1e-4);
}

// ---------------------------------------------------------------------------
// vmvar — [64,16] -> ([64] mean, [64] var)
// ---------------------------------------------------------------------------

fn ir_vmvar(rows: i64, w: i64) -> Func {
    let mut b = FuncBuilder::new("vmvar_diff");
    let x = b.global("x", DType::F32, (rows * w) as usize, CacheHint::Warm);
    let mean = b.global("mean", DType::F32, rows as usize, CacheHint::Warm);
    let var = b.global("var", DType::F32, rows as usize, CacheHint::Warm);
    b.for_range(0, rows, 1, |b, r| {
        let wc = b.const_i(w);
        let base = b.mul(r, wc);
        b.for_range(0, w, 1, |b, i| {
            let idx = b.add(base, i);
            let v = b.load(x, idx);
            let s = b.load(mean, r);
            let s2 = b.add(s, v);
            b.store(mean, r, s2);
            let sq = b.mul(v, v);
            let m2 = b.load(var, r);
            let m22 = b.add(m2, sq);
            b.store(var, r, m22);
        });
        let wf = b.const_f(w as f64);
        let s = b.load(mean, r);
        let m = b.div(s, wf);
        b.store(mean, r, m);
        let m2 = b.load(var, r);
        let ex2 = b.div(m2, wf);
        let msq = b.mul(m, m);
        let v = b.sub(ex2, msq);
        b.store(var, r, v);
    });
    b.finish(&[])
}

#[test]
fn diff_vmvar_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_3B);
    let xs = normals(&mut rng, 64 * 16);

    let f = ir_vmvar(64, 16);
    let mut mem = Memory::for_func(&f);
    mem.write_f32(Kernel::buf(&f, "x"), &xs);
    interp(&f, &[], &mut mem).unwrap();
    let ir_mean = mem.read_f32(Kernel::buf(&f, "mean"));
    let ir_var = mem.read_f32(Kernel::buf(&f, "var"));

    let sim = rt.execute("vmvar", &[Tensor::f32(xs, &[64, 16]).unwrap()]).unwrap();
    assert_close("vmvar mean", &ir_mean, sim[0].as_f32().unwrap(), 1e-3);
    assert_close("vmvar var", &ir_var, sim[1].as_f32().unwrap(), 1e-3);
}

// ---------------------------------------------------------------------------
// attention — [1,4,64,16] causal MHA
// ---------------------------------------------------------------------------
//
// The IR has no exp op, so the softmax cannot be expressed in Aquas-IR;
// the two linear-algebra stages (score GEMM, probability-weighted value
// sum) run through the interpreter and the softmax runs on the host in
// f32 (the exact two-pass formula `runtime::sim::attend` uses). The
// composition must agree with the runtime's one-shot attention entry.

const AH: i64 = 4; // heads
const AT: i64 = 64; // sequence
const AD: i64 = 16; // head dim

/// Stage 1: s[h, i, j] = q[h, i, :] · k[h, j, :] for all (i, j).
fn ir_attn_scores() -> Func {
    let mut b = FuncBuilder::new("attn_scores_diff");
    let q = b.global("q", DType::F32, (AH * AT * AD) as usize, CacheHint::Warm);
    let k = b.global("k", DType::F32, (AH * AT * AD) as usize, CacheHint::Warm);
    let s = b.global("s", DType::F32, (AH * AT * AT) as usize, CacheHint::Warm);
    b.for_range(0, AH, 1, |b, h| {
        b.for_range(0, AT, 1, |b, i| {
            b.for_range(0, AT, 1, |b, j| {
                let td = b.const_i(AT * AD);
                let hq = b.mul(h, td);
                let dd = b.const_i(AD);
                let iq = b.mul(i, dd);
                let jq = b.mul(j, dd);
                let qrow0 = b.add(hq, iq);
                let krow0 = b.add(hq, jq);
                let mut acc = b.const_f(0.0);
                for d in 0..AD {
                    let off = b.const_i(d);
                    let qi = b.add(qrow0, off);
                    let qv = b.load(q, qi);
                    let ki = b.add(krow0, off);
                    let kv = b.load(k, ki);
                    let p = b.mul(qv, kv);
                    acc = b.add(acc, p);
                }
                let tt = b.const_i(AT * AT);
                let hs = b.mul(h, tt);
                let tc = b.const_i(AT);
                let is = b.mul(i, tc);
                let s0 = b.add(hs, is);
                let sidx = b.add(s0, j);
                b.store(s, sidx, acc);
            });
        });
    });
    b.finish(&[])
}

/// Stage 2: out[h, i, :] = Σ_j p[h, i, j] · v[h, j, :] (p is zero beyond
/// the causal window, so the full-j sum is the masked sum).
fn ir_attn_weighted_sum() -> Func {
    let mut b = FuncBuilder::new("attn_wsum_diff");
    let p = b.global("p", DType::F32, (AH * AT * AT) as usize, CacheHint::Warm);
    let v = b.global("v", DType::F32, (AH * AT * AD) as usize, CacheHint::Warm);
    let o = b.global("o", DType::F32, (AH * AT * AD) as usize, CacheHint::Warm);
    b.for_range(0, AH, 1, |b, h| {
        b.for_range(0, AT, 1, |b, i| {
            b.for_range(0, AT, 1, |b, j| {
                let tt = b.const_i(AT * AT);
                let hp = b.mul(h, tt);
                let tc = b.const_i(AT);
                let ip = b.mul(i, tc);
                let p0 = b.add(hp, ip);
                let pidx = b.add(p0, j);
                let pv = b.load(p, pidx);
                let td = b.const_i(AT * AD);
                let hv = b.mul(h, td);
                let dd = b.const_i(AD);
                let jv = b.mul(j, dd);
                let v0 = b.add(hv, jv);
                let iv = b.mul(i, dd);
                let o0 = b.add(hv, iv);
                for d in 0..AD {
                    let off = b.const_i(d);
                    let vi = b.add(v0, off);
                    let vv = b.load(v, vi);
                    let prod = b.mul(pv, vv);
                    let oi = b.add(o0, off);
                    let ov = b.load(o, oi);
                    let acc = b.add(ov, prod);
                    b.store(o, oi, acc);
                }
            });
        });
    });
    b.finish(&[])
}

#[test]
fn diff_attention_tolerance_equal() {
    let rt = runtime();
    let mut rng = Rng::new(0xD1F_A7);
    let n = (AH * AT * AD) as usize;
    let q = normals(&mut rng, n);
    let k = normals(&mut rng, n);
    let v = normals(&mut rng, n);

    // IR stage 1: raw dot-product scores.
    let f1 = ir_attn_scores();
    let mut mem = Memory::for_func(&f1);
    mem.write_f32(Kernel::buf(&f1, "q"), &q);
    mem.write_f32(Kernel::buf(&f1, "k"), &k);
    interp(&f1, &[], &mut mem).unwrap();
    let scores = mem.read_f32(Kernel::buf(&f1, "s"));

    // Host: causal scaled softmax per (head, query) row, two-pass in f32
    // exactly as the backend's `attend` computes it.
    let scale = 1.0f32 / (AD as f32).sqrt();
    let (h, t) = (AH as usize, AT as usize);
    let mut probs = vec![0.0f32; h * t * t];
    for hi in 0..h {
        for i in 0..t {
            let row = &scores[hi * t * t + i * t..hi * t * t + i * t + (i + 1)];
            let mut mx = f32::NEG_INFINITY;
            let scaled: Vec<f32> = row
                .iter()
                .map(|&x| {
                    let s = x * scale;
                    mx = mx.max(s);
                    s
                })
                .collect();
            let exps: Vec<f32> = scaled.iter().map(|&s| (s - mx).exp()).collect();
            let denom: f32 = exps.iter().sum();
            for (j, &e) in exps.iter().enumerate() {
                probs[hi * t * t + i * t + j] = e / denom;
            }
        }
    }

    // IR stage 2: probability-weighted value sum.
    let f2 = ir_attn_weighted_sum();
    let mut mem = Memory::for_func(&f2);
    mem.write_f32(Kernel::buf(&f2, "p"), &probs);
    mem.write_f32(Kernel::buf(&f2, "v"), &v);
    interp(&f2, &[], &mut mem).unwrap();
    let ir_out = mem.read_f32(Kernel::buf(&f2, "o"));

    // Runtime path: the one-shot causal MHA entry.
    let shape = [1usize, AH as usize, AT as usize, AD as usize];
    let sim = rt
        .execute(
            "attention",
            &[
                Tensor::f32(q, &shape).unwrap(),
                Tensor::f32(k, &shape).unwrap(),
                Tensor::f32(v, &shape).unwrap(),
            ],
        )
        .unwrap();
    assert_close("attention", &ir_out, sim[0].as_f32().unwrap(), 2e-3);
}

// ---------------------------------------------------------------------------
// Sweep: every manifest entry is accounted for (fail loudly if a future
// entry lands without a cross-check).
// ---------------------------------------------------------------------------

#[test]
fn every_aot_entry_is_cross_checked() {
    let rt = runtime();
    // Kernel entries with an interp-vs-sim differential test in this file.
    let diffed = [
        "attention", "gf2mm", "mcov", "phong", "vdecomp", "vdist3", "vfsmax", "vmadot",
        "vmvar", "vrgb2yuv",
    ];
    // The transformer serving entries cannot be expressed in Aquas-IR
    // (no exp op → no softmax/SwiGLU); they are pinned by their own
    // cross-path tests instead: teacher-forcing prefill/decode
    // consistency and causality in runtime/sim.rs, the host-side
    // softmax(QKᵀ)V oracle and the bitwise batched-vs-entry decode
    // comparison in runtime_integration.rs.
    let serving = ["llm_decode", "llm_prefill"];
    for name in rt.entry_names() {
        assert!(
            diffed.contains(&name.as_str()) || serving.contains(&name.as_str()),
            "entry `{name}` has no differential test in golden_diff.rs and is not a \
             known serving entry — add one"
        );
    }
}
