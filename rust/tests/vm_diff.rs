//! Differential testing of the register-bytecode VM (`ir::vm`) against
//! the tree-walking reference interpreter (`ir::interp`).
//!
//! The VM is the hot path (compile once, execute flat typed memory); the
//! tree-walker is the semantic oracle. This file demands *exact*
//! agreement — return values, full memory image (bit-exact through the
//! typed arena views), integer register file, and `ExecStats` — on:
//!
//! - a seeded random-program sweep (nested `for`s with carried values,
//!   `if`/`else`, loads/stores, bulk transfers including overlapping
//!   same-buffer moves, irf traffic, mixed int/float dataflow, `exp`);
//! - handcrafted temporal-level programs (`copy_issue`/`copy_wait`);
//! - error paths (both engines must fail identically, including stats
//!   counted up to the failure point);
//! - the traced-mode contract (a live trace sink routes through the
//!   tree-walker and produces the same access stream);
//! - the mid-end (`ir::passes`): every pass alone and the full pipeline,
//!   applied to every fuzz seed, must keep the program observationally
//!   identical (outputs, memory image, irf, error strings) on *both*
//!   engines — the machine-checked "semantics-preserving" claim.

use aquas::bench_harness::interp::{
    check_equivalent, check_fuel_equivalent, check_opt_equivalent, random_program,
    seed_memory,
};
use aquas::interface::cache::CacheHint;
use aquas::interface::model::InterfaceId;
use aquas::interface::TransactionKind;
use aquas::ir::builder::FuncBuilder;
use aquas::ir::func::{BufferId, Value};
use aquas::ir::interp::{self, ExecStats, Memory, Val};
use aquas::ir::ops::{Op, OpKind};
use aquas::ir::{vm, Func};
use aquas::runtime::DType;

// ---------------------------------------------------------------------------
// The fuzz sweep
// ---------------------------------------------------------------------------

#[test]
fn fuzz_vm_equals_tree_walker_on_150_seeds() {
    for seed in 0..150u64 {
        let f = random_program(seed);
        check_equivalent(&f, seed).unwrap_or_else(|e| {
            panic!(
                "seed {seed}: {e}\nprogram:\n{}",
                aquas::ir::printer::print_func(&f)
            )
        });
    }
}

#[test]
fn fuzz_fuel_determinism_on_150_seeds() {
    // For every seeded program and every budget in {0, 1, spent/2,
    // spent-1, spent}: the walker and the VM must agree exactly — same
    // verdict (including the fuel-abort error), same partial ExecStats,
    // same final Fuel state, same memory image — and exactly-enough fuel
    // must reproduce the unfueled run bitwise. (`check_fuel_equivalent`
    // also proves unlimited fuel is bitwise-invisible on both engines.)
    for seed in 0..150u64 {
        let f = random_program(seed);
        check_fuel_equivalent(&f, seed).unwrap_or_else(|e| {
            panic!(
                "seed {seed}: {e}\nprogram:\n{}",
                aquas::ir::printer::print_func(&f)
            )
        });
    }
}

#[test]
fn fuzz_programs_exercise_the_op_mix() {
    // The generator must actually cover the constructs the sweep claims:
    // across a window of seeds we expect loops, branches, copies, irf
    // traffic, and both int and float arithmetic to appear.
    let (mut fors, mut ifs, mut copies, mut irf, mut exps) = (0, 0, 0, 0, 0);
    for seed in 0..60u64 {
        let f = random_program(seed);
        fors += f.count_ops(|k| matches!(k, OpKind::For));
        ifs += f.count_ops(|k| matches!(k, OpKind::If));
        copies +=
            f.count_ops(|k| matches!(k, OpKind::Transfer { .. } | OpKind::Copy { .. }));
        irf += f.count_ops(|k| matches!(k, OpKind::ReadIrf(_) | OpKind::WriteIrf(_)));
        exps += f.count_ops(|k| matches!(k, OpKind::Exp));
    }
    assert!(fors > 10, "loops: {fors}");
    assert!(ifs > 5, "ifs: {ifs}");
    assert!(copies > 10, "copies: {copies}");
    assert!(irf > 10, "irf ops: {irf}");
    assert!(exps > 3, "exp ops: {exps}");
}

// ---------------------------------------------------------------------------
// The mid-end sweep: every pass, every seed, both engines
// ---------------------------------------------------------------------------

#[test]
fn fuzz_each_pass_alone_is_semantics_preserving_on_150_seeds() {
    use aquas::ir::passes::{run_pass, Pass};
    for seed in 0..150u64 {
        let f = random_program(seed);
        for pass in Pass::ALL {
            let mut p = f.clone();
            run_pass(&mut p, pass).unwrap_or_else(|e| {
                panic!("seed {seed}: {} produced invalid IR: {e}", pass.name())
            });
            check_opt_equivalent(&f, &p, seed).unwrap_or_else(|e| {
                panic!(
                    "seed {seed}, pass {}: {e}\nprogram:\n{}",
                    pass.name(),
                    aquas::ir::printer::print_func(&f)
                )
            });
        }
    }
}

#[test]
fn fuzz_full_pipeline_is_semantics_preserving_on_150_seeds() {
    use aquas::ir::passes::{optimize, OptLevel};
    for seed in 0..150u64 {
        let f = random_program(seed);
        let (opt, _) = optimize(&f, OptLevel::O2)
            .unwrap_or_else(|e| panic!("seed {seed}: pipeline produced invalid IR: {e}"));
        check_opt_equivalent(&f, &opt, seed).unwrap_or_else(|e| {
            panic!(
                "seed {seed}: {e}\nprogram:\n{}\noptimized:\n{}",
                aquas::ir::printer::print_func(&f),
                aquas::ir::printer::print_func(&opt)
            )
        });
    }
}

// ---------------------------------------------------------------------------
// Temporal level: issue/wait
// ---------------------------------------------------------------------------

fn issue_wait_func() -> Func {
    let mut b = FuncBuilder::new("issue_wait");
    let g = b.global("g", DType::I32, 8, CacheHint::Unknown);
    let s = b.scratchpad("s", DType::I32, 8, 1);
    let zero = b.const_i(0);
    let mut f = {
        b.transfer(s, zero, g, zero, 0); // placeholder replaced below
        b.finish(&[])
    };
    let issue = f.add_op(Op::new(
        OpKind::CopyIssue {
            itfc: InterfaceId(0),
            dst: BufferId(1),
            src: BufferId(0),
            size: 32,
            kind: TransactionKind::Load,
            tag: 3,
            after: vec![],
        },
        vec![Value(0), Value(0)],
        vec![],
    ));
    let wait = f.add_op(Op::new(OpKind::CopyWait { tag: 3 }, vec![], vec![]));
    let ret = f.entry.ops.pop().unwrap();
    f.entry.ops.pop(); // placeholder transfer
    f.entry.ops.push(issue);
    f.entry.ops.push(wait);
    f.entry.ops.push(ret);
    f
}

#[test]
fn issue_wait_equivalent_and_completes_at_wait() {
    let f = issue_wait_func();
    check_equivalent(&f, 11).unwrap();
    let mut mem = Memory::for_func(&f);
    mem.write_i32(BufferId(0), &[1, 2, 3, 4, 5, 6, 7, 8]);
    let mut stats = ExecStats::default();
    vm::compile(&f).unwrap().run_with_stats(&[], &mut mem, &mut stats).unwrap();
    assert_eq!(mem.read_i32(BufferId(1)), vec![1, 2, 3, 4, 5, 6, 7, 8]);
    assert_eq!(stats.transfers, 1);
    assert_eq!(stats.transfer_bytes, 32);
    // Issue ops charge the simulated §4.1 completion cycle (timing-only
    // stat, identical across engines — check_equivalent above pinned the
    // equality; here pin the value against the closed-form recurrence).
    let expect = aquas::interface::latency::sequence_latency(
        &aquas::interface::model::MemInterface::cpu_port(),
        TransactionKind::Load,
        &[32],
    );
    assert_eq!(stats.dma_cycles, expect);
    let mut m2 = Memory::for_func(&f);
    m2.write_i32(BufferId(0), &[1, 2, 3, 4, 5, 6, 7, 8]);
    let mut s2 = ExecStats::default();
    interp::run_with_stats(&f, &[], &mut m2, &mut s2).unwrap();
    assert_eq!(s2.dma_cycles, expect, "tree-walker charges the same DMA clock");
}

#[test]
fn wait_without_issue_fails_identically() {
    let mut b = FuncBuilder::new("orphan_wait");
    let _g = b.global("g", DType::I32, 4, CacheHint::Unknown);
    let mut f = b.finish(&[]);
    let wait = f.add_op(Op::new(OpKind::CopyWait { tag: 9 }, vec![], vec![]));
    let at = f.entry.ops.len() - 1;
    f.entry.ops.insert(at, wait);
    let mut m1 = Memory::for_func(&f);
    let mut m2 = Memory::for_func(&f);
    let e1 = interp::run(&f, &[], &mut m1).unwrap_err().to_string();
    let e2 = vm::compile(&f).unwrap().run(&[], &mut m2).unwrap_err().to_string();
    assert_eq!(e1, e2);
    assert!(e1.contains("unknown tag 9"), "got: {e1}");
}

// ---------------------------------------------------------------------------
// Error paths
// ---------------------------------------------------------------------------

#[test]
fn division_by_zero_counts_and_fails_identically() {
    let mut b = FuncBuilder::new("divzero");
    let x = b.const_i(7);
    let z = b.const_i(0);
    let q = b.div(x, z);
    let f = b.finish(&[q]);
    let mut m1 = Memory::for_func(&f);
    let mut m2 = Memory::for_func(&f);
    let mut s1 = ExecStats::default();
    let mut s2 = ExecStats::default();
    let e1 = interp::run_with_stats(&f, &[], &mut m1, &mut s1).unwrap_err().to_string();
    let e2 = vm::compile(&f)
        .unwrap()
        .run_with_stats(&[], &mut m2, &mut s2)
        .unwrap_err()
        .to_string();
    assert_eq!(e1, e2);
    // The op is counted before the fault in both engines.
    assert_eq!(s1, s2);
    assert_eq!(s1.arith_ops, 1);
}

#[test]
fn intrinsic_errors_identically() {
    let mut b = FuncBuilder::new("isax");
    let x = b.const_i(1);
    b.intrinsic("vdot", vec![x], false);
    let f = b.finish(&[]);
    let mut m1 = Memory::for_func(&f);
    let mut m2 = Memory::for_func(&f);
    let mut s1 = ExecStats::default();
    let mut s2 = ExecStats::default();
    let e1 = interp::run_with_stats(&f, &[], &mut m1, &mut s1).unwrap_err().to_string();
    let e2 = vm::compile(&f)
        .unwrap()
        .run_with_stats(&[], &mut m2, &mut s2)
        .unwrap_err()
        .to_string();
    assert_eq!(e1, e2);
    assert_eq!(s1.intrinsic_calls, 1);
    assert_eq!(s1, s2);
}

#[test]
fn misaligned_transfer_fails_identically() {
    let mut b = FuncBuilder::new("misalign");
    let g = b.global("g", DType::I32, 8, CacheHint::Unknown);
    let s = b.scratchpad("s", DType::I32, 8, 1);
    let zero = b.const_i(0);
    b.transfer(s, zero, g, zero, 6); // 6 bytes: not a 4B multiple
    let f = b.finish(&[]);
    let mut m1 = Memory::for_func(&f);
    let mut m2 = Memory::for_func(&f);
    let e1 = interp::run(&f, &[], &mut m1).unwrap_err().to_string();
    let e2 = vm::compile(&f).unwrap().run(&[], &mut m2).unwrap_err().to_string();
    assert_eq!(e1, e2);
    assert!(e1.contains("4B-aligned"), "got: {e1}");
}

// ---------------------------------------------------------------------------
// Traced-mode contract
// ---------------------------------------------------------------------------

#[test]
fn traced_mode_routes_through_tree_walker_with_same_accesses() {
    let f = random_program(77);
    let mut m1 = Memory::for_func(&f);
    seed_memory(&f, &mut m1, 77);
    let mut m2 = m1.clone();
    let args: Vec<Val> = f.params.iter().map(|_| Val::I(2)).collect();
    // Direct tree-walker trace.
    let mut s1 = ExecStats::default();
    let mut t1 = Some(Vec::new());
    let r1 = interp::run_traced(&f, &args, &mut m1, &mut s1, &mut t1);
    // VM-surface trace (must fall back to the oracle).
    let mut s2 = ExecStats::default();
    let mut t2 = Some(Vec::new());
    let r2 = vm::run_traced(&f, &args, &mut m2, &mut s2, &mut t2);
    match (&r1, &r2) {
        (Ok(a), Ok(b)) => assert_eq!(a, b),
        (Err(e1), Err(e2)) => assert_eq!(e1.to_string(), e2.to_string()),
        other => panic!("verdicts diverge: {other:?}"),
    }
    assert_eq!(s1, s2);
    assert_eq!(t1, t2, "trace streams diverge");
}

// ---------------------------------------------------------------------------
// Compile-once reuse
// ---------------------------------------------------------------------------

#[test]
fn compiled_function_is_reusable_across_runs_and_memories() {
    let f = aquas::bench_harness::interp::ir_vmadot(16, 16);
    let compiled = vm::compile(&f).unwrap();
    for seed in [1u64, 2, 3] {
        let mut m1 = Memory::for_func(&f);
        seed_memory(&f, &mut m1, seed);
        let mut m2 = m1.clone();
        interp::run(&f, &[], &mut m1).unwrap();
        compiled.run(&[], &mut m2).unwrap();
        let y = f.buffer_by_name("y").unwrap();
        assert_eq!(m1.read_f32(y), m2.read_f32(y), "seed {seed}");
    }
}
