//! Event-driven DMA simulator vs the closed-form §4.1/§4.3 models on
//! *real* transaction traces: every case-study kernel's ISAX is
//! synthesized and its chosen transaction schedule replayed through
//! `interface::dmasim`.
//!
//! Pinned claims (the acceptance contract of the dmasim subsystem):
//! - the uncontended mixed-kind replay reproduces the scheduler's
//!   closed-form per-interface cycles *exactly*;
//! - per interface, the same-kind single-stream sub-traces match
//!   `sequence_latency` exactly — stores and loads alike;
//! - the §4.3 `T_k` estimate is exact for store traces and within its
//!   documented 50% bound for load traces, measured *against the
//!   simulator* (the executable form of the latency.rs doc comment).

use aquas::interface::dmasim;
use aquas::interface::latency::{sequence_latency, tk_estimate, TransactionKind};
use aquas::synthesis::scheduling::simulate_schedule;
use aquas::synthesis::synthesize;
use aquas::workloads::{graphics_kernels, table2_kernels};

#[test]
fn every_kernel_schedule_replay_matches_closed_form() {
    let mut covered = 0usize;
    for k in table2_kernels().into_iter().chain(graphics_kernels()) {
        let synth = synthesize(&k.isax.func, &k.itfcs, &k.synth_opts)
            .unwrap_or_else(|e| panic!("{}: synth {e}", k.name));
        if synth.schedule.items.is_empty() {
            continue; // fully elided ISAXs schedule no bulk transactions
        }
        covered += 1;
        let sim = simulate_schedule(&synth.schedule, &k.itfcs)
            .unwrap_or_else(|e| panic!("{}: replay {e}", k.name));
        assert_eq!(sim.conflict_cycles, 0, "{}: uncontended replay conflicted", k.name);

        // 1. Mixed-kind replay == the scheduler's closed form, exactly.
        for &(id, closed) in &synth.schedule.per_itfc {
            assert_eq!(
                sim.itfc_cycles(id),
                closed,
                "{}: {id} simulated != closed-form schedule latency",
                k.name
            );
        }
        assert_eq!(sim.makespan, synth.schedule.mem_latency(), "{}: makespan", k.name);

        // 2./3. Same-kind single-stream sub-traces per interface.
        for (kid, itfc) in k.itfcs.iter() {
            for kind in [TransactionKind::Load, TransactionKind::Store] {
                // Per-op segment lists (T_k's shape) + the flat trace.
                let mut segments: Vec<Vec<usize>> = Vec::new();
                for item in &synth.schedule.items {
                    if item.itfc != kid || item.kind != kind {
                        continue;
                    }
                    if item.offset == 0 || segments.is_empty() {
                        segments.push(Vec::new());
                    }
                    segments.last_mut().expect("pushed above").push(item.size);
                }
                let sizes: Vec<usize> = segments.iter().flatten().copied().collect();
                if sizes.is_empty() {
                    continue;
                }
                let sim_cycles = dmasim::simulate_sizes(itfc, kind, &sizes);
                let closed = sequence_latency(itfc, kind, &sizes);
                assert_eq!(
                    sim_cycles, closed,
                    "{}: {kind:?} sub-trace on {} diverged from sequence_latency",
                    k.name, itfc.name
                );
                let tk = tk_estimate(itfc, kind, &segments);
                match kind {
                    TransactionKind::Store => {
                        // The documented §4.3 store bound is exact for
                        // *legal* (integral-beat) sizes; a runt tail
                        // segment (e.g. mcov.vs's 36B store → [32, 4] on
                        // the 8B bus) is billed fractional beats by T_k
                        // but a full padded beat by the hardware/sim, so
                        // each runt may open a sub-beat gap, never more.
                        let runts =
                            sizes.iter().filter(|&&m| m % itfc.width != 0).count() as f64;
                        let gap = sim_cycles as f64 - tk;
                        assert!(
                            gap >= -1e-6 && gap <= runts + 1e-6,
                            "{}: store T_k {tk} vs simulated {sim_cycles} \
                             ({runts} runt segments) on {}",
                            k.name,
                            itfc.name
                        );
                    }
                    TransactionKind::Load => {
                        let rel = (tk - sim_cycles as f64).abs() / (sim_cycles as f64).max(1.0);
                        assert!(
                            rel <= 0.5,
                            "{}: load T_k {tk} vs simulated {sim_cycles} (rel {rel:.3}) on {}",
                            k.name,
                            itfc.name
                        );
                    }
                }
            }
        }
    }
    assert!(covered >= 3, "only {covered} kernels scheduled bulk transactions");
}
