//! Adversarial no-panic harness (robustness tier).
//!
//! The contract under test: **no input — hostile, malformed, or merely
//! unlucky — may abort the process.** Every front door (pattern parser,
//! JSON parser, budget/fault spec parsers, IR verifier, compiler,
//! both execution engines) must either succeed or return a diagnostic
//! `Error`; panicking is a bug even when the input is garbage.
//!
//! Every case runs under `catch_unwind`, so a regression reports *which*
//! seeded input aborted instead of killing the test binary. Well over
//! 200 distinct seeded inputs are exercised across the five fronts:
//!
//! 1. 80 seeded random programs through verify → optimize → both engines;
//! 2. 60 seeded *corrupted* programs (ghost operands, truncated
//!    operand/result lists, arity-breaking kind swaps) through the
//!    verifier — which must reject them with `Err`, never abort — and,
//!    when a mutation happens to stay valid, through both engines;
//! 3. 48 garbage pattern strings (plus pathological nesting) through
//!    `Pattern::try_parse`;
//! 4. 48 garbage / truncated / byte-flipped JSON documents (plus
//!    100k-deep nesting) through `Json::parse`;
//! 5. 40 garbage budget / fault specs through `CompileBudget::parse`
//!    and `FaultPlan::parse`, and every Table-2 kernel compiled under
//!    starved budgets (exhaustion degrades, never fails or panics);
//! 6. 40 garbage explore-space specs (plus a fixed hostile list) through
//!    `DesignSpace::parse`, and a legal-but-extreme `Explorer::run`
//!    whose only candidate is infeasible — recorded, never fatal.
//!
//! Corruption deliberately mutates **existing** ops via `op_mut` and
//! never inserts out-of-range `OpRef`s into regions: a bogus `OpRef` is
//! an arena-indexing bug by construction (`Func::op` would panic before
//! the verifier could see it), not a reachable user input.

use std::panic::{catch_unwind, AssertUnwindSafe};

use aquas::bench_harness::interp::{default_args, random_program, seed_memory};
use aquas::compiler::{self, CompileBudget, CompileOptions};
use aquas::coordinator::FaultPlan;
use aquas::dse::{DesignSpace, Explorer};
use aquas::egraph::Pattern;
use aquas::ir::interp::{self, Memory};
use aquas::ir::passes::{optimize, OptLevel};
use aquas::ir::{verifier, vm, Func, OpKind, OpRef, Value};
use aquas::util::json::Json;
use aquas::workloads;

/// Tiny deterministic PRNG (xorshift64*) so every hostile input is
/// reproducible from its seed alone.
fn rng(seed: u64) -> impl FnMut() -> u64 {
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }
}

/// Run `f` under `catch_unwind`; on panic, fail the test naming the case.
fn must_not_panic<T>(label: &str, f: impl FnOnce() -> T) -> T {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(v) => v,
        Err(e) => {
            let msg = e
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| e.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic payload>".to_string());
            panic!("PANIC on {label}: {msg}");
        }
    }
}

/// Execute a (verified) program through both engines. Runtime `Error`s
/// are fine — out-of-bounds, div-by-zero and friends are diagnostics,
/// not aborts — but both calls must return.
fn run_both(f: &Func, seed: u64) {
    let args = default_args(f);
    let mut mem = Memory::for_func(f);
    seed_memory(f, &mut mem, seed);
    let _ = interp::run(f, &args, &mut mem);
    let mut mem = Memory::for_func(f);
    seed_memory(f, &mut mem, seed);
    let _ = vm::run(f, &args, &mut mem);
}

// ---------------------------------------------------------------------
// Front 1: well-formed random programs (80 seeds).
// ---------------------------------------------------------------------

#[test]
fn random_programs_never_panic() {
    for seed in 0..80u64 {
        must_not_panic(&format!("random program seed {seed}"), || {
            let f = random_program(seed);
            assert!(
                verifier::verify(&f).is_ok(),
                "seed {seed}: generator emitted an unverifiable program"
            );
            run_both(&f, seed);
            // The full mid-end over the same program, then both engines
            // again on the optimized form.
            if let Ok((opt, _)) = optimize(&f, OptLevel::O2) {
                run_both(&opt, seed);
            }
        });
    }
}

// ---------------------------------------------------------------------
// Front 2: corrupted programs (60 seeds). The verifier is the gate: it
// must *reject* (or, if the mutation is benign, accept) every mutant
// without aborting, and anything it accepts must also execute safely.
// ---------------------------------------------------------------------

#[test]
fn corrupted_programs_are_rejected_not_aborted() {
    let mut rejected = 0usize;
    for seed in 0..60u64 {
        let mut next = rng(seed);
        let mut f = random_program(seed % 24);
        let n_ops = f.num_ops();
        if n_ops == 0 {
            continue;
        }
        let target = OpRef((next() % n_ops as u64) as u32);
        let mutation = next() % 4;
        match mutation {
            // Ghost operand: a Value id no op defines. The verifier's
            // scope check must catch it before any type lookup.
            0 => {
                let ghost = Value(1_000_000 + (next() % 1_000) as u32);
                let op = f.op_mut(target);
                if op.operands.is_empty() {
                    op.operands.push(ghost);
                } else {
                    let i = (next() as usize) % op.operands.len();
                    op.operands[i] = ghost;
                }
            }
            // Truncated operand list: arity violation.
            1 => {
                let op = f.op_mut(target);
                let keep = if op.operands.is_empty() {
                    0
                } else {
                    (next() as usize) % op.operands.len()
                };
                op.operands.truncate(keep);
            }
            // Truncated result list.
            2 => {
                f.op_mut(target).results.truncate(0);
            }
            // Arity-breaking kind swap (keeps regions/operands as-is).
            _ => {
                let op = f.op_mut(target);
                op.kind = match next() % 3 {
                    0 => OpKind::Select,
                    1 => OpKind::Neg,
                    _ => OpKind::Add,
                };
            }
        }
        must_not_panic(&format!("corrupted program seed {seed} mutation {mutation}"), || {
            match verifier::verify(&f) {
                Ok(()) => run_both(&f, seed),
                Err(_) => rejected += 1,
            }
        });
    }
    // The corruption must actually bite — if (almost) every mutant still
    // verifies, the mutations are too tame to test the gate.
    assert!(rejected >= 12, "only {rejected}/60 mutants rejected; corruption too weak");
}

// ---------------------------------------------------------------------
// Front 3: hostile pattern text (48 cases + pathological nesting).
// ---------------------------------------------------------------------

#[test]
fn garbage_patterns_never_panic() {
    const ATOMS: &[&str] = &[
        "(", ")", "?", "?x", "?x?y", "f", "add", "mul", "const:0", "const:", ":",
        "\u{0}", " ", "\t", "((", "))", "?)", "-1e309", "\\", "\"",
    ];
    for seed in 0..48u64 {
        let mut next = rng(seed ^ 0x9A77);
        let len = 1 + (next() % 24) as usize;
        let mut text = String::new();
        for _ in 0..len {
            text.push_str(ATOMS[(next() as usize) % ATOMS.len()]);
        }
        must_not_panic(&format!("pattern seed {seed}: {text:?}"), || {
            let _ = Pattern::try_parse(&text);
        });
    }
    // Recursion bomb: must hit the depth cap, not the stack guard.
    must_not_panic("pattern nesting bomb", || {
        let bomb = "(f ".repeat(10_000);
        assert!(Pattern::try_parse(&bomb).is_err());
    });
}

// ---------------------------------------------------------------------
// Front 4: hostile JSON (48 cases + nesting bombs).
// ---------------------------------------------------------------------

#[test]
fn garbage_json_never_panics() {
    const ATOMS: &[&str] = &[
        "{", "}", "[", "]", ":", ",", "\"", "\\", "\\u12", "null", "nul", "true",
        "tru3", "-", "1e309", "1.2.3", "\u{0}", " ", "\"k\"", "0",
    ];
    let valid = r#"{"name":"k","shape":[4,4],"args":{"n":4,"scale":1.5},"ok":true}"#;
    for seed in 0..48u64 {
        let label;
        let text = if seed % 2 == 0 {
            // Random atom soup.
            let mut next = rng(seed ^ 0x15_0A);
            let len = 1 + (next() % 24) as usize;
            let mut t = String::new();
            for _ in 0..len {
                t.push_str(ATOMS[(next() as usize) % ATOMS.len()]);
            }
            label = format!("json soup seed {seed}: {t:?}");
            t
        } else {
            // Byte-flip / truncate a valid document.
            let mut next = rng(seed ^ 0xF11F);
            let mut bytes = valid.as_bytes().to_vec();
            if next() % 2 == 0 {
                let i = (next() as usize) % bytes.len();
                bytes[i] ^= (1 + next() % 255) as u8;
            } else {
                bytes.truncate((next() as usize) % bytes.len());
            }
            label = format!("json mutation seed {seed}");
            String::from_utf8_lossy(&bytes).into_owned()
        };
        must_not_panic(&label, || {
            let _ = Json::parse(&text);
        });
    }
    // Nesting bombs: the depth cap must fire before the stack does.
    for bomb in [
        "[".repeat(100_000),
        "{\"k\":".repeat(100_000),
        format!("{}1{}", "[".repeat(100_000), "]".repeat(100_000)),
    ] {
        must_not_panic("json nesting bomb", || {
            assert!(Json::parse(&bomb).is_err());
        });
    }
}

// ---------------------------------------------------------------------
// Front 5: garbage specs + starved compiles.
// ---------------------------------------------------------------------

#[test]
fn garbage_specs_never_panic() {
    const ATOMS: &[&str] = &[
        "iters", "nodes", "matches", "external", "rounds", "coredown", "corestall",
        "dmaerr", "surge", "seed", "=", "@", "..", ",", "-1", "1e309", "nan", "x",
        "0x10", "", " ",
    ];
    for seed in 0..40u64 {
        let mut next = rng(seed ^ 0x5bec);
        let len = 1 + (next() % 12) as usize;
        let mut text = String::new();
        for _ in 0..len {
            text.push_str(ATOMS[(next() as usize) % ATOMS.len()]);
        }
        must_not_panic(&format!("spec seed {seed}: {text:?}"), || {
            let _ = CompileBudget::parse(&text);
            let _ = FaultPlan::parse(&text);
        });
    }
}

// ---------------------------------------------------------------------
// Front 6: hostile explore-space specs + an extreme-but-legal search.
// ---------------------------------------------------------------------

#[test]
fn garbage_explore_specs_never_panic() {
    // Fixed hostile cases: each must come back as a diagnostic error
    // carrying the `explore space` prefix, never a panic.
    for spec in [
        "width=0",
        "width=",
        "width=18446744073709551616",
        "burst=8..1",
        "burst=1..99999999999",
        "banks=�",
        "unroll=1|0|",
        "inflight=1e3",
        "frobnicate=4",
        "=",
        "width==4",
        "width=4..",
        "width=..8",
    ] {
        must_not_panic(&format!("explore spec {spec:?}"), || {
            let e = DesignSpace::parse(spec).expect_err(spec).to_string();
            assert!(e.contains("explore space"), "{spec}: {e}");
        });
    }
    // Seeded atom soup: parse must return (Ok or Err), never abort.
    const ATOMS: &[&str] = &[
        "width", "burst", "inflight", "banks", "unroll", "=", "|", "..", ",",
        "0", "1", "8", "64", "999", "-4", "x", "", " ", "\u{0}", "1e309",
    ];
    for seed in 0..40u64 {
        let mut next = rng(seed ^ 0xD5E5);
        let len = 1 + (next() % 16) as usize;
        let mut text = String::new();
        for _ in 0..len {
            text.push_str(ATOMS[(next() as usize) % ATOMS.len()]);
        }
        must_not_panic(&format!("explore spec seed {seed}: {text:?}"), || {
            let _ = DesignSpace::parse(&text);
        });
    }
}

#[test]
fn extreme_explore_run_records_infeasible_without_panicking() {
    // Every axis pinned at its cap. unroll=16 cannot divide the attention
    // tile's 8 static trips, so the sole candidate is infeasible — the
    // run must record it diagnostically and still return Ok (the §6.1
    // baselines ride along and keep the frontier non-empty).
    must_not_panic("extreme explore run", || {
        let mut ex = Explorer::demo();
        ex.space = DesignSpace::parse("width=64,burst=64,inflight=16,banks=16,unroll=16")
            .unwrap_or_else(|e| panic!("cap-edge spec must parse: {e}"));
        let r = ex.run().unwrap_or_else(|e| panic!("extreme run errored: {e}"));
        assert_eq!(r.infeasible.len(), 1, "the cap-edge point must be infeasible");
        assert!(!r.frontier.is_empty(), "baselines must keep the frontier alive");
    });
}

#[test]
fn starved_compiles_degrade_without_panicking() {
    // Budget exhaustion is observable, never fatal: every Table-2 kernel
    // under three increasingly starved budgets must still produce
    // verified IR (and must never abort).
    let budgets = [
        CompileBudget { iter_limit: 0, external_budget: 0, pass_rounds: 0, ..Default::default() },
        CompileBudget { iter_limit: 1, node_limit: 64, match_limit: 4, ..Default::default() },
        CompileBudget { iter_limit: 2, node_limit: 512, match_limit: 32, external_budget: 1, pass_rounds: 1 },
    ];
    for kernel in workloads::table2_kernels() {
        let isaxes = [kernel.isax];
        for (bi, budget) in budgets.iter().enumerate() {
            let opts = CompileOptions { budget: budget.clone(), opt_level: 2 };
            must_not_panic(&format!("starved compile {} budget {bi}", kernel.name), || {
                let r = compiler::compile(&kernel.software, &isaxes, &opts)
                    .unwrap_or_else(|e| panic!("{}: starved compile errored: {e}", kernel.name));
                assert!(
                    verifier::verify(&r.func).is_ok(),
                    "{}: starved compile produced unverifiable IR",
                    kernel.name
                );
            });
        }
    }
}
