//! Runtime integration: execute every AOT entry through the runtime.
//!
//! Works on a clean checkout: when `make artifacts` has not been run the
//! runtime serves the built-in simulated manifest (`runtime/sim.rs`),
//! whose entries implement the same golden models as the Pallas
//! artifacts. These tests validate the entry numerics and the serving
//! coordinator end-to-end either way.

use aquas::runtime::{Runtime, Tensor};

fn runtime() -> Runtime {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::load(&dir).expect("runtime load (simulated fallback) cannot fail")
}

#[test]
fn manifest_lists_all_entries() {
    let rt = runtime();
    let names = rt.entry_names();
    for expected in [
        "attention", "gf2mm", "llm_decode", "llm_prefill", "mcov", "phong",
        "vdecomp", "vdist3", "vfsmax", "vmadot", "vmvar", "vrgb2yuv",
    ] {
        assert!(names.iter().any(|n| n == expected), "missing entry {expected}");
    }
}

#[test]
fn vdecomp_unpacks_bits() {
    let rt = runtime();
    // word 0 = 0b1011 -> bits [1,1,0,1,0,...]
    let mut words = vec![0i32; 16];
    words[0] = 0b1011;
    let out = rt
        .execute("vdecomp", &[Tensor::i32(words, &[16]).unwrap()])
        .unwrap();
    let bits = out[0].as_i32().unwrap();
    assert_eq!(&bits[..5], &[1, 1, 0, 1, 0]);
    assert_eq!(bits.len(), 512);
    assert!(bits[4..].iter().all(|&b| b == 0));
}

#[test]
fn gf2mm_identity_roundtrip() {
    let rt = runtime();
    // a * I = a over GF(2)
    let mut eye = vec![0i32; 64 * 64];
    for i in 0..64 {
        eye[i * 64 + i] = 1;
    }
    let mut a = vec![0i32; 64 * 64];
    let mut rng = aquas::util::rng::Rng::new(7);
    for x in a.iter_mut() {
        *x = rng.below(2) as i32;
    }
    let out = rt
        .execute(
            "gf2mm",
            &[
                Tensor::i32(a.clone(), &[64, 64]).unwrap(),
                Tensor::i32(eye, &[64, 64]).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out[0].as_i32().unwrap(), a.as_slice());
}

#[test]
fn vdist3_matches_host_computation() {
    let rt = runtime();
    let mut rng = aquas::util::rng::Rng::new(11);
    let p: Vec<f32> = (0..256 * 3).map(|_| rng.normal() as f32).collect();
    let q: Vec<f32> = (0..256 * 3).map(|_| rng.normal() as f32).collect();
    let out = rt
        .execute(
            "vdist3",
            &[
                Tensor::f32(p.clone(), &[256, 3]).unwrap(),
                Tensor::f32(q.clone(), &[256, 3]).unwrap(),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    for i in 0..256 {
        let want: f32 = (0..3)
            .map(|d| {
                let diff = p[i * 3 + d] - q[i * 3 + d];
                diff * diff
            })
            .sum();
        assert!((got[i] - want).abs() < 1e-4, "i={i} got {} want {want}", got[i]);
    }
}

#[test]
fn llm_prefill_then_decode() {
    let rt = runtime();
    let m = rt.manifest().model.clone();
    let ids = Tensor::i32(vec![1; m.prefill_len], &[1, m.prefill_len]).unwrap();
    let outs = rt.execute("llm_prefill", &[ids]).unwrap();
    assert_eq!(outs.len(), 3);
    let logits = &outs[0];
    assert_eq!(logits.shape(), &[1, m.prefill_len, m.vocab]);
    assert!(logits.as_f32().unwrap().iter().all(|x| x.is_finite()));

    // One decode step at position prefill_len.
    let next = Tensor::i32(vec![2], &[1, 1]).unwrap();
    let pos = Tensor::i32(vec![m.prefill_len as i32], &[1]).unwrap();
    let douts = rt
        .execute("llm_decode", &[next, outs[1].clone(), outs[2].clone(), pos])
        .unwrap();
    assert_eq!(douts[0].shape(), &[1, m.vocab]);
    assert!(douts[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn execute_rejects_bad_shapes() {
    let rt = runtime();
    let bad = Tensor::i32(vec![0; 4], &[2, 2]).unwrap();
    assert!(rt.execute("gf2mm", &[bad.clone(), bad]).is_err());
}

#[test]
fn execute_rejects_unknown_entry() {
    let rt = runtime();
    assert!(rt.execute("nonexistent", &[]).is_err());
}

// ---------------------------------------------------------------------------
// Serving coordinator over the real artifacts
// ---------------------------------------------------------------------------

use aquas::coordinator::{Coordinator, CoordinatorConfig, SchedulePolicy};

#[test]
fn coordinator_serves_batch_to_completion() {
    let rt = runtime();
    let mut coord = Coordinator::new(&rt, CoordinatorConfig::default());
    let a = coord.submit(vec![1, 2, 3, 4], 4).unwrap();
    let b = coord.submit(vec![9, 8, 7], 3).unwrap();
    let metrics = coord.run_to_completion().unwrap();
    assert_eq!(metrics.len(), 2);
    assert_eq!(metrics[0].id, a);
    assert_eq!(metrics[1].id, b);
    assert_eq!(metrics[0].generated.len(), 4);
    assert_eq!(metrics[1].generated.len(), 3);
    for m in &metrics {
        assert!(m.ttft_us > 0);
        assert!(m.sim_base_cycles > m.sim_isax_cycles);
    }
}

#[test]
fn coordinator_greedy_decode_is_deterministic() {
    let rt = runtime();
    let gen = |policy| {
        let mut c = Coordinator::new(&rt, CoordinatorConfig { policy, ..Default::default() });
        c.submit(vec![5, 6, 7, 8, 9], 6).unwrap();
        c.run_to_completion().unwrap()[0].generated.clone()
    };
    let g1 = gen(SchedulePolicy::DecodeFirst);
    let g2 = gen(SchedulePolicy::PrefillFirst);
    // Scheduling policy must not change single-request numerics.
    assert_eq!(g1, g2);
}

#[test]
fn coordinator_decode_matches_unbatched_reference() {
    // Interleaved serving of two requests must produce the same tokens as
    // serving each alone (KV isolation).
    let rt = runtime();
    let solo = |prompt: Vec<i32>| {
        let mut c = Coordinator::new(&rt, CoordinatorConfig::default());
        c.submit(prompt, 5).unwrap();
        c.run_to_completion().unwrap()[0].generated.clone()
    };
    let s1 = solo(vec![10, 20, 30]);
    let s2 = solo(vec![40, 50, 60, 70]);

    let mut c = Coordinator::new(
        &rt,
        CoordinatorConfig { policy: SchedulePolicy::PrefillFirst, ..Default::default() },
    );
    c.submit(vec![10, 20, 30], 5).unwrap();
    c.submit(vec![40, 50, 60, 70], 5).unwrap();
    let both = c.run_to_completion().unwrap();
    assert_eq!(both[0].generated, s1, "request 0 perturbed by batching");
    assert_eq!(both[1].generated, s2, "request 1 perturbed by batching");
}

#[test]
fn coordinator_rejects_oversized_requests() {
    let rt = runtime();
    let m = rt.manifest().model.clone();
    let mut coord = Coordinator::new(&rt, CoordinatorConfig::default());
    assert!(coord.submit(vec![], 4).is_err(), "empty prompt");
    assert!(
        coord.submit(vec![1; m.prefill_len + 1], 4).is_err(),
        "prompt beyond prefill window"
    );
    assert!(
        coord.submit(vec![1; 4], m.max_seq).is_err(),
        "generation beyond KV capacity"
    );
}

#[test]
fn coordinator_respects_max_active() {
    let rt = runtime();
    let mut coord = Coordinator::new(
        &rt,
        CoordinatorConfig {
            policy: SchedulePolicy::PrefillFirst,
            max_active: 2,
            ..Default::default()
        },
    );
    for i in 0..5 {
        coord.submit(vec![i as i32 + 1; 4], 2).unwrap();
    }
    let metrics = coord.run_to_completion().unwrap();
    assert_eq!(metrics.len(), 5);
}

#[test]
fn decode_fuel_ceiling_sheds_runaway_requests() {
    // A tiny per-token fuel allowance: every decode tick blows past it,
    // so each request is cut off after its first decoded token and
    // counted as shed — but still retires cleanly with its prefix.
    let rt = runtime();
    let mut coord = Coordinator::new(
        &rt,
        CoordinatorConfig { decode_fuel_per_token: Some(1e-9), ..Default::default() },
    );
    coord.submit(vec![1, 2, 3, 4], 8).unwrap();
    coord.submit(vec![9, 8, 7], 8).unwrap();
    let metrics = coord.run_to_completion().unwrap();
    assert_eq!(metrics.len(), 2, "shed requests still deliver their prefix");
    assert_eq!(coord.shed_requests(), 2, "both runaway sequences counted as shed");
    for m in &metrics {
        assert!(
            m.generated.len() < 8,
            "request {} ran to its full budget despite the fuel ceiling",
            m.id
        );
        assert!(!m.generated.is_empty(), "prefill token must survive the cut");
    }
    assert!(coord.kv_stats().leak_free(), "early retirement leaked KV blocks");
}

#[test]
fn decode_fuel_none_is_bitwise_invisible() {
    let run = |fuel: Option<f64>| {
        let rt = runtime();
        let mut c = Coordinator::new(
            &rt,
            CoordinatorConfig { decode_fuel_per_token: fuel, ..Default::default() },
        );
        c.submit(vec![5, 6, 7, 8], 6).unwrap();
        let m = c.run_to_completion().unwrap();
        (m[0].generated.clone(), m[0].sim_isax_cycles, c.shed_requests())
    };
    let (g_off, cyc_off, shed_off) = run(None);
    // A generous ceiling never fires either and must match exactly.
    let (g_on, cyc_on, shed_on) = run(Some(f64::INFINITY));
    assert_eq!(g_off, g_on);
    assert_eq!(cyc_off.to_bits(), cyc_on.to_bits());
    assert_eq!(shed_off, 0);
    assert_eq!(shed_on, 0);
}

#[test]
fn attention_artifact_matches_serving_numerics() {
    // The standalone attention artifact (the L1 kernel's golden model)
    // must agree with a direct softmax(QK^T)V on the host.
    let rt = runtime();
    let mut rng = aquas::util::rng::Rng::new(99);
    let (b, h, t, d) = (1usize, 4usize, 64usize, 16usize);
    let n = b * h * t * d;
    let q: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.5).collect();
    let k: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.5).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.5).collect();
    let shape = [b, h, t, d];
    let out = rt
        .execute(
            "attention",
            &[
                Tensor::f32(q.clone(), &shape).unwrap(),
                Tensor::f32(k.clone(), &shape).unwrap(),
                Tensor::f32(v.clone(), &shape).unwrap(),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();

    // host reference (causal)
    let scale = 1.0 / (d as f32).sqrt();
    for hi in 0..h {
        for qi in 0..t {
            let mut scores = vec![f32::NEG_INFINITY; t];
            for ki in 0..=qi {
                let mut s = 0.0;
                for di in 0..d {
                    s += q[(hi * t + qi) * d + di] * k[(hi * t + ki) * d + di];
                }
                scores[ki] = s * scale;
            }
            let mx = scores.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - mx).exp()).collect();
            let denom: f32 = exps.iter().sum();
            for di in 0..d {
                let mut o = 0.0;
                for ki in 0..=qi {
                    o += exps[ki] / denom * v[(hi * t + ki) * d + di];
                }
                let gotv = got[(hi * t + qi) * d + di];
                assert!(
                    (gotv - o).abs() < 1e-3,
                    "h{hi} q{qi} d{di}: {gotv} vs {o}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Paged-KV continuous-batching engine
// ---------------------------------------------------------------------------

use aquas::coordinator::PagedKvConfig;
use aquas::runtime::DecodeSlot;

#[test]
fn batched_decode_path_matches_llm_decode_entry_bitwise() {
    // The serving hot path (Runtime::decode_batch over gathered working
    // sets) must be numerically identical to the per-token llm_decode
    // entry — same TinyLlm::step under the hood, zero drift allowed.
    let rt = runtime();
    let m = rt.manifest().model.clone();
    let mut ids = vec![3i32, 14, 15, 9];
    let plen = ids.len();
    ids.resize(m.prefill_len, 0);
    let outs = rt
        .execute("llm_prefill", &[Tensor::i32(ids, &[1, m.prefill_len]).unwrap()])
        .unwrap();
    let (k0, v0) = (outs[1].clone(), outs[2].clone());
    let tok = 42i32;

    // Entry path: tensors in, tensors out.
    let entry = rt
        .execute(
            "llm_decode",
            &[
                Tensor::i32(vec![tok], &[1, 1]).unwrap(),
                k0.clone(),
                v0.clone(),
                Tensor::i32(vec![plen as i32], &[1]).unwrap(),
            ],
        )
        .unwrap();
    let entry_logits = entry[0].as_f32().unwrap();

    // Batched path: in-place slices.
    let mut kc = k0.as_f32().unwrap().to_vec();
    let mut vc = v0.as_f32().unwrap().to_vec();
    assert_eq!(kc.len(), rt.kv_elems());
    let logits = {
        let mut slots =
            [DecodeSlot { token: tok, pos: plen, kc: &mut kc, vc: &mut vc }];
        rt.decode_batch(&mut slots).unwrap()
    };
    assert_eq!(logits[0].as_slice(), entry_logits, "logits diverge");
    // The written KV slot must match the entry's output caches too.
    assert_eq!(kc.as_slice(), entry[1].as_f32().unwrap(), "K cache diverges");
    assert_eq!(vc.as_slice(), entry[2].as_f32().unwrap(), "V cache diverges");
}

#[test]
fn tiny_pool_preempts_and_still_matches_solo_tokens() {
    // A deliberately starved block pool: two long generations cannot both
    // hold their full working sets, so decode growth must preempt —
    // and recompute re-admission must reproduce the exact token streams.
    let rt = runtime();
    let solo = |prompt: Vec<i32>| {
        let mut c = Coordinator::new(&rt, CoordinatorConfig::default());
        c.submit(prompt, 16).unwrap();
        c.run_to_completion().unwrap()[0].generated.clone()
    };
    let s1 = solo(vec![10, 20, 30, 40]);
    let s2 = solo(vec![50, 60, 70, 80]);

    let mut c = Coordinator::new(
        &rt,
        CoordinatorConfig {
            kv: PagedKvConfig { block_slots: 4, num_blocks: 7 },
            ..Default::default()
        },
    );
    c.submit(vec![10, 20, 30, 40], 16).unwrap();
    c.submit(vec![50, 60, 70, 80], 16).unwrap();
    let metrics = c.run_to_completion().unwrap();
    assert_eq!(metrics.len(), 2);
    assert_eq!(metrics[0].generated, s1, "request 0 perturbed by preemption");
    assert_eq!(metrics[1].generated, s2, "request 1 perturbed by preemption");
    assert!(
        c.preemptions() > 0,
        "7 blocks x 4 slots cannot hold two 20-slot sequences without preemption"
    );
    assert!(metrics.iter().any(|m| m.preemptions > 0));
    let kv = c.kv_stats();
    assert!(kv.leak_free(), "blocks leaked after preemption churn: {kv:?}");
}

#[test]
fn oversized_request_for_the_pool_is_rejected_up_front() {
    let rt = runtime();
    let mut c = Coordinator::new(
        &rt,
        CoordinatorConfig {
            kv: PagedKvConfig { block_slots: 4, num_blocks: 3 },
            ..Default::default()
        },
    );
    // 4 + 16 = 20 slots > 3 blocks x 4 slots: must be rejected, not
    // deadlock the scheduler later.
    assert!(c.submit(vec![1, 2, 3, 4], 16).is_err());
    // A request that fits the pool is fine.
    assert!(c.submit(vec![1, 2, 3, 4], 6).is_ok());
    let metrics = c.run_to_completion().unwrap();
    assert_eq!(metrics[0].generated.len(), 6);
    assert!(c.kv_stats().leak_free());
}

#[test]
fn fair_policy_matches_decode_first_tokens() {
    // Scheduling policy reorders work in time but must never change the
    // greedy numerics of any request.
    let rt = runtime();
    let run = |policy| {
        let mut c = Coordinator::new(&rt, CoordinatorConfig { policy, ..Default::default() });
        for i in 0..5 {
            c.submit(vec![i as i32 * 7 + 1, 2, 3], 4).unwrap();
        }
        let ms = c.run_to_completion().unwrap();
        assert!(c.kv_stats().leak_free());
        ms.into_iter().map(|m| (m.id, m.generated)).collect::<Vec<_>>()
    };
    let df = run(SchedulePolicy::DecodeFirst);
    let fair = run(SchedulePolicy::Fair);
    let pf = run(SchedulePolicy::PrefillFirst);
    assert_eq!(df, fair, "Fair diverged from DecodeFirst");
    assert_eq!(df, pf, "PrefillFirst diverged from DecodeFirst");
}

#[test]
fn trace_arrivals_gate_admission_and_ttft_accounts_queueing() {
    use aquas::coordinator::TraceSpec;
    let rt = runtime();
    let m = rt.manifest().model.clone();
    let spec =
        TraceSpec { n: 6, seed: 5, rate: 1.0, plen: (4, 8), gen: (4, 6), ..Default::default() };
    let reqs = spec.generate(m.vocab, m.prefill_len);
    let mut c = Coordinator::new(&rt, CoordinatorConfig::default());
    let ids = c.submit_trace(&reqs).unwrap();
    assert_eq!(ids, (0..6).collect::<Vec<u64>>());
    let metrics = c.run_to_completion().unwrap();
    assert_eq!(metrics.len(), 6);
    // The engine can never finish before the last request has arrived.
    let last_arrival = reqs.last().unwrap().arrive_ms;
    assert!(
        c.sim_now_ms() >= last_arrival,
        "clock {} ended before final arrival {last_arrival}",
        c.sim_now_ms()
    );
    for m in &metrics {
        assert!(m.ttft_us > 0);
        assert!(!m.generated.is_empty());
    }
}
