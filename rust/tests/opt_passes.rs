//! Property tests for the mid-end pass framework (`ir::passes`).
//!
//! `tests/vm_diff.rs` proves the passes *observationally* safe (same
//! outputs/memory/irf/errors on both engines); this file pins the
//! structural properties the safety argument rests on:
//!
//! - **anchors are sacred**: DCE (and the full pipeline) never deletes a
//!   side-effecting op — stores, scratchpad writes, irf writes, bulk
//!   transfers/copies, issue/wait pairs, interface traffic, intrinsics —
//!   nor any op whose value feeds a `return`;
//! - **idempotence**: the pipeline is a real fixpoint — running it a
//!   second time reports zero rewrites and leaves the function
//!   bit-identical (`Func: PartialEq`);
//! - **verifier acceptance**: every post-pass function (each pass alone
//!   and the pipeline) passes the IR verifier, on fuzz programs and on
//!   every AOT kernel.

use aquas::bench_harness::interp::{aot_cases, random_program};
use aquas::ir::ops::OpKind;
use aquas::ir::passes::{optimize, run_pass, OptLevel, Pass};
use aquas::ir::{verifier, Func};

/// Count the effectful anchors no pass may remove.
fn count_anchors(f: &Func) -> usize {
    f.count_ops(|k| {
        matches!(
            k,
            OpKind::Store(_)
                | OpKind::WriteSmem(_)
                | OpKind::WriteIrf(_)
                | OpKind::Transfer { .. }
                | OpKind::Copy { .. }
                | OpKind::StoreItfc { .. }
                | OpKind::CopyIssue { .. }
                | OpKind::CopyWait { .. }
                | OpKind::Intrinsic(_)
        )
    })
}

/// The op (if any) that defines each value returned by `f`.
fn return_feeders(f: &Func) -> Vec<OpKind> {
    let defs = f.def_map();
    let mut feeders = Vec::new();
    f.walk(|_, op| {
        if matches!(op.kind, OpKind::Return) {
            for v in &op.operands {
                if let Some(d) = defs[v.0 as usize] {
                    feeders.push(f.op(d).kind.clone());
                }
            }
        }
    });
    feeders
}

#[test]
fn dce_never_removes_anchors_or_return_feeders() {
    for seed in 0..120u64 {
        let orig = random_program(seed);
        let anchors = count_anchors(&orig);
        let feeders = return_feeders(&orig);
        let mut f = orig.clone();
        run_pass(&mut f, Pass::Dce).unwrap();
        assert_eq!(
            count_anchors(&f),
            anchors,
            "seed {seed}: DCE removed an effectful anchor"
        );
        // DCE rewrites no operands, so every value a return consumes must
        // still be defined by an op of the same kind.
        assert_eq!(
            return_feeders(&f),
            feeders,
            "seed {seed}: DCE orphaned a returned value"
        );
    }
}

#[test]
fn full_pipeline_never_removes_anchors() {
    for seed in 0..120u64 {
        let orig = random_program(seed);
        let anchors = count_anchors(&orig);
        let (opt, _) = optimize(&orig, OptLevel::O2).unwrap();
        assert_eq!(
            count_anchors(&opt),
            anchors,
            "seed {seed}: the pipeline removed an effectful anchor"
        );
    }
}

#[test]
fn pipeline_is_idempotent_on_fuzz_programs() {
    for seed in 0..120u64 {
        let f = random_program(seed);
        let (opt, _) = optimize(&f, OptLevel::O2).unwrap();
        let (opt2, stats2) = optimize(&opt, OptLevel::O2).unwrap();
        assert_eq!(
            stats2.total(),
            0,
            "seed {seed}: second pipeline run still rewrote: {stats2}"
        );
        assert_eq!(opt2, opt, "seed {seed}: fixpoint run mutated the function");
    }
}

#[test]
fn pipeline_is_idempotent_on_aot_kernels() {
    for (name, f) in aot_cases() {
        let (opt, _) = optimize(&f, OptLevel::O2).unwrap();
        let (opt2, stats2) = optimize(&opt, OptLevel::O2).unwrap();
        assert_eq!(stats2.total(), 0, "{name}: second run rewrote: {stats2}");
        assert_eq!(opt2, opt, "{name}: fixpoint run mutated the function");
    }
}

#[test]
fn verifier_accepts_every_post_pass_function() {
    // `run_pass` verifies internally, but the property stands on its own:
    // re-check with the public verifier entry point, on fuzz programs and
    // the real kernels, for each pass alone and the whole pipeline.
    for seed in 0..60u64 {
        let orig = random_program(seed);
        for pass in Pass::ALL {
            let mut f = orig.clone();
            run_pass(&mut f, pass).unwrap();
            verifier::verify(&f)
                .unwrap_or_else(|e| panic!("seed {seed}, {}: {e}", pass.name()));
        }
        let (opt, _) = optimize(&orig, OptLevel::O2).unwrap();
        verifier::verify(&opt).unwrap_or_else(|e| panic!("seed {seed}, pipeline: {e}"));
    }
    for (name, orig) in aot_cases() {
        for pass in Pass::ALL {
            let mut f = orig.clone();
            run_pass(&mut f, pass).unwrap();
            verifier::verify(&f).unwrap_or_else(|e| panic!("{name}, {}: {e}", pass.name()));
        }
        let (opt, _) = optimize(&orig, OptLevel::O2).unwrap();
        verifier::verify(&opt).unwrap_or_else(|e| panic!("{name}, pipeline: {e}"));
    }
}
