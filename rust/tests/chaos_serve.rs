//! Chaos-hardened serving: deterministic fault injection through the
//! SoC coordinator — core deaths, stall windows, DMA error retries and
//! load surges — checked against the serving-layer invariants:
//!
//! - an **empty** fault plan is bitwise invisible (every metric, clock
//!   and counter identical to a fault-free build);
//! - faults change *when* and *where* sequences run, never *what* they
//!   generate — surviving token streams match an ample single-engine
//!   replay bitwise, id by id;
//! - every shard's block accounting returns to empty (evacuation frees
//!   the dead core's blocks);
//! - a seeded fault schedule replays byte-identically;
//! - unservable plans surface as diagnostic errors, never hangs.
//!
//! Works on a clean checkout (simulated-manifest fallback), like
//! `soc_serve.rs`.

use aquas::coordinator::{
    Coordinator, CoordinatorConfig, FaultPlan, SocConfig, SocCoordinator, TraceRequest, TraceSpec,
};
use aquas::runtime::Runtime;

fn runtime() -> Runtime {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::load(&dir).expect("runtime load (simulated fallback) cannot fail")
}

fn trace(rt: &Runtime, n: usize, seed: u64) -> Vec<TraceRequest> {
    let model = rt.manifest().model.clone();
    let spec = TraceSpec {
        n,
        seed,
        rate: 0.0, // everything at t = 0: every core holds work when faults land
        plen: (3, 6),
        gen: (2, 5),
        ..Default::default()
    };
    spec.generate_capped(model.vocab, model.prefill_len, model.max_seq)
}

/// Ground truth: the same requests through a plain single engine with an
/// ample KV pool — per-id token streams any chaos schedule must
/// reproduce bitwise for every sequence it completes.
fn ample_tokens(rt: &Runtime, reqs: &[TraceRequest]) -> Vec<(u64, Vec<i32>)> {
    let mut c = Coordinator::new(rt, CoordinatorConfig::default());
    c.submit_trace(reqs).expect("1-core submit");
    let metrics = c.run_to_completion().expect("1-core replay");
    metrics.iter().map(|m| (m.id, m.generated.clone())).collect()
}

/// Run `reqs` through a `cores`-wide SoC under `plan`; returns
/// `(per-id tokens, Debug-rendered stats, elapsed ms, full metrics debug)`.
fn run_chaos(
    rt: &Runtime,
    cores: usize,
    plan: FaultPlan,
    reqs: &[TraceRequest],
) -> (Vec<(u64, Vec<i32>)>, String, f64, String) {
    let mut soc =
        SocCoordinator::new(rt, SocConfig { cores, faults: plan, ..Default::default() });
    soc.submit_trace(reqs).expect("soc submit");
    let metrics = soc.run_to_completion().expect("soc replay");
    let stats = soc.stats();
    let n = reqs.len() as u64;
    // Accounting: every submitted request either completed or was shed
    // by graceful degradation — nothing lost, nothing duplicated.
    assert_eq!(metrics.len() as u64 + stats.shed_requests, n, "requests lost: {stats:?}");
    for w in metrics.windows(2) {
        assert!(w[0].id < w[1].id, "duplicate or unsorted SoC ids");
    }
    for (k, kv) in stats.per_core_kv.iter().enumerate() {
        assert!(kv.leak_free(), "core {k} shard leaked under chaos: {kv:?}");
    }
    let toks = metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
    (toks, format!("{stats:?}"), soc.sim_elapsed_ms(), format!("{metrics:?}"))
}

/// Assert each completed stream matches the ample ground truth bitwise.
fn assert_tokens_preserved(got: &[(u64, Vec<i32>)], truth: &[(u64, Vec<i32>)]) {
    for (id, toks) in got {
        let t = truth
            .iter()
            .find(|(tid, _)| tid == id)
            .unwrap_or_else(|| panic!("chaos invented sequence id {id}"));
        assert_eq!(toks, &t.1, "req {id} token stream perturbed by faults");
    }
}

#[test]
fn empty_fault_plan_is_bitwise_invisible() {
    let rt = runtime();
    let reqs = trace(&rt, 16, 31);
    // A bare seed with no fault events is still the empty plan: nothing
    // is armed, and the run must be byte-for-byte the fault-free run —
    // same metrics, same counters, same clock.
    let bare_seed = FaultPlan { seed: 42, ..Default::default() };
    assert!(bare_seed.is_empty());
    let (toks_a, stats_a, t_a, metrics_a) = run_chaos(&rt, 4, FaultPlan::default(), &reqs);
    let (toks_b, stats_b, t_b, metrics_b) = run_chaos(&rt, 4, bare_seed, &reqs);
    assert_eq!(toks_a, toks_b);
    assert_eq!(metrics_a, metrics_b, "empty plan perturbed metrics");
    assert_eq!(stats_a, stats_b, "empty plan perturbed counters");
    assert_eq!(t_a, t_b, "empty plan perturbed the clock");
    assert!(stats_a.contains("faults_injected: 0"));
}

#[test]
fn killing_a_core_preserves_every_surviving_token_bitwise() {
    let rt = runtime();
    let reqs = trace(&rt, 16, 5);
    let truth = ample_tokens(&rt, &reqs);
    let plan = FaultPlan::parse("coredown=1@0").expect("plan parses");
    let (toks, stats, _, _) = run_chaos(&rt, 4, plan, &reqs);
    assert_tokens_preserved(&toks, &truth);
    // The death itself is one injected fault, and round-robin dispatch
    // put a quarter of the trace on core 1 — the watchdog must have
    // evacuated it (leak-free shards are asserted inside run_chaos).
    assert!(stats.contains("faults_injected: 1"), "death not recorded: {stats}");
    assert!(!stats.contains("evacuated_seqs: 0"), "nothing evacuated: {stats}");
}

#[test]
fn chaos_replay_is_bitwise_deterministic() {
    let rt = runtime();
    let reqs = trace(&rt, 12, 17);
    let plan = FaultPlan::parse("coredown=1@0,corestall=2@0..30,dmaerr=0.05,seed=11,surge=1.5@0..60")
        .expect("plan parses");
    let a = run_chaos(&rt, 4, plan.clone(), &reqs);
    let b = run_chaos(&rt, 4, plan, &reqs);
    assert_eq!(a.0, b.0, "token streams diverged across replays");
    assert_eq!(a.3, b.3, "metrics diverged across replays");
    assert_eq!(a.1, b.1, "fault counters diverged across replays");
    assert_eq!(a.2, b.2, "clocks diverged across replays");
}

#[test]
fn dma_errors_retry_on_the_simulated_clock_without_corrupting_tokens() {
    let rt = runtime();
    let reqs = trace(&rt, 10, 23);
    let truth = ample_tokens(&rt, &reqs);
    let plan = FaultPlan::parse("dmaerr=0.25,seed=3").expect("plan parses");
    let (toks, stats, elapsed, _) = run_chaos(&rt, 2, plan, &reqs);
    // ECC retries are billed in simulated beats, not data: streams stay
    // bitwise intact while the retry counter shows the plan was live.
    assert_tokens_preserved(&toks, &truth);
    assert!(!stats.contains("dma_retries: 0"), "p=0.25 never retried: {stats}");
    assert!(elapsed.is_finite() && elapsed > 0.0);
}

#[test]
fn a_fully_stalled_soc_recovers_instead_of_deadlocking() {
    let rt = runtime();
    let reqs = trace(&rt, 8, 41);
    let truth = ample_tokens(&rt, &reqs);
    // Both cores stalled from t = 0 with all work queued: simulated time
    // cannot advance, so the deadlock release must retire the
    // earliest-ending window (core 0 at 40 ms) by decree and let the
    // watchdog shuffle the rest.
    let plan = FaultPlan::parse("corestall=0@0..40,corestall=1@0..80").expect("plan parses");
    let (toks, stats, elapsed, _) = run_chaos(&rt, 2, plan, &reqs);
    assert_tokens_preserved(&toks, &truth);
    assert!(stats.contains("faults_injected: 2"), "both stalls must fire: {stats}");
    assert!(elapsed >= 40.0, "release must fast-forward past the window: {elapsed}");
}

#[test]
fn load_surge_inflates_the_clock_but_not_the_tokens() {
    let rt = runtime();
    // 4 requests over 2 cores fit one decode batch each: no queueing, no
    // degradation ladder — the surged run does exactly the clean run's
    // work at twice the modelled cost, so its clock is strictly slower.
    let reqs = trace(&rt, 4, 29);
    let truth = ample_tokens(&rt, &reqs);
    let (_, _, clean_ms, _) = run_chaos(&rt, 2, FaultPlan::default(), &reqs);
    let plan = FaultPlan::parse("surge=2@0..1000000").expect("plan parses");
    let (toks, stats, surged_ms, _) = run_chaos(&rt, 2, plan, &reqs);
    assert_tokens_preserved(&toks, &truth);
    assert!(stats.contains("faults_injected: 1"), "surge never armed: {stats}");
    assert!(
        surged_ms > clean_ms,
        "a 2x surge over the whole run must cost time: {surged_ms} vs {clean_ms}"
    );
}

#[test]
fn unservable_fault_plans_error_instead_of_hanging() {
    let rt = runtime();
    let reqs = trace(&rt, 4, 3);

    // A plan naming a core the SoC does not have is rejected on the
    // first round, not silently ignored.
    let plan = FaultPlan::parse("coredown=5@0").expect("spec itself is well-formed");
    let mut soc =
        SocCoordinator::new(&rt, SocConfig { cores: 2, faults: plan, ..Default::default() });
    soc.submit_trace(&reqs).expect("soc submit");
    let err = soc.run_to_completion().expect_err("5 >= 2 cores must fail").to_string();
    assert!(err.contains("fault plan"), "wrong diagnostic: {err}");

    // Killing every core with work outstanding has no recovery target:
    // the evacuation must report the outage as an error, never spin.
    let plan = FaultPlan::parse("coredown=0@0,coredown=1@0").expect("plan parses");
    let mut soc =
        SocCoordinator::new(&rt, SocConfig { cores: 2, faults: plan, ..Default::default() });
    soc.submit_trace(&reqs).expect("soc submit");
    let err = soc.run_to_completion().expect_err("total outage must fail").to_string();
    assert!(err.contains("no surviving core"), "wrong diagnostic: {err}");
}
