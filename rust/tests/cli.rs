//! CLI surface tests for the `aquas` binary: exit codes, usage text, and
//! the artifact-free subcommands (everything here must pass on a clean
//! checkout with no `make artifacts` step).

use std::process::{Command, Output};

fn aquas(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_aquas"))
        .args(args)
        .output()
        .expect("spawn aquas binary")
}

#[test]
fn help_exits_zero_with_usage() {
    let out = aquas(&["help"]);
    assert!(out.status.success(), "exit: {:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"), "no usage in: {text}");
    assert!(text.contains("synth"), "missing synth in: {text}");
    assert!(text.contains("serve"), "missing serve in: {text}");
}

#[test]
fn no_arguments_prints_usage_and_exits_zero() {
    let out = aquas(&[]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_command_exits_one_with_usage() {
    let out = aquas(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown command"), "stderr: {err}");
    assert!(err.contains("USAGE"), "no usage on stderr: {err}");
}

#[test]
fn ir_levels_prints_table1_summary() {
    let out = aquas(&["ir-levels"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Table 1"), "missing title: {text}");
    for level in ["Functional", "Architectural", "Temporal"] {
        assert!(text.contains(level), "missing {level}: {text}");
    }
}

#[test]
fn synth_demo_fir7_shows_all_refinement_levels() {
    let out = aquas(&["synth", "--demo", "fir7"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("transfer"), "functional level missing");
    assert!(text.contains("copy_issue"), "temporal level missing");
    assert!(text.contains("module isax_fir7"), "verilog missing");
}

#[test]
fn compile_vmadot_reports_match() {
    let out = aquas(&["compile", "vmadot"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("kernel: vmadot"), "got: {text}");
    assert!(text.contains("vmadot"), "no match report: {text}");
    assert!(text.contains("isax"), "no intrinsic in lowered program: {text}");
}

#[test]
fn compile_unknown_kernel_fails() {
    let out = aquas(&["compile", "nonexistent_kernel"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown kernel"));
}

#[test]
fn serve_runs_artifact_free() {
    // The runtime falls back to the built-in simulated manifest, so
    // `aquas serve` must work on a clean checkout.
    let out = aquas(&["serve", "-n", "2"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("platform:"), "no platform line: {text}");
    assert!(text.contains("req 0:"), "no request metrics: {text}");
    assert!(text.contains("req 1:"), "second request missing: {text}");
}
