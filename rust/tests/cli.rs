//! CLI surface tests for the `aquas` binary: exit codes, usage text, and
//! the artifact-free subcommands (everything here must pass on a clean
//! checkout with no `make artifacts` step).

use std::process::{Command, Output};

fn aquas(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_aquas"))
        .args(args)
        .output()
        .expect("spawn aquas binary")
}

#[test]
fn help_exits_zero_with_usage() {
    let out = aquas(&["help"]);
    assert!(out.status.success(), "exit: {:?}", out.status);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"), "no usage in: {text}");
    assert!(text.contains("synth"), "missing synth in: {text}");
    assert!(text.contains("serve"), "missing serve in: {text}");
    assert!(text.contains("interp"), "missing interp bench in: {text}");
}

#[test]
fn no_arguments_prints_usage_and_exits_zero() {
    let out = aquas(&[]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_command_exits_one_with_usage() {
    let out = aquas(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown command"), "stderr: {err}");
    assert!(err.contains("USAGE"), "no usage on stderr: {err}");
}

#[test]
fn ir_levels_prints_table1_summary() {
    let out = aquas(&["ir-levels"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Table 1"), "missing title: {text}");
    for level in ["Functional", "Architectural", "Temporal"] {
        assert!(text.contains(level), "missing {level}: {text}");
    }
}

#[test]
fn synth_demo_fir7_shows_all_refinement_levels() {
    let out = aquas(&["synth", "--demo", "fir7"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("transfer"), "functional level missing");
    assert!(text.contains("copy_issue"), "temporal level missing");
    assert!(text.contains("module isax_fir7"), "verilog missing");
}

#[test]
fn synth_demo_timing_sim_reports_deltas() {
    let out = aquas(&["synth", "--demo", "fir7", "--timing", "sim"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("--timing sim"), "timing section missing: {text}");
    assert!(text.contains("closed-form"), "no closed-form column: {text}");
    assert!(text.contains("simulated"), "no simulated column: {text}");
    // Uncontended fir7 replays agree with the closed form exactly.
    assert!(text.contains("delta +0"), "expected a zero delta row: {text}");
}

#[test]
fn compile_vmadot_reports_match() {
    let out = aquas(&["compile", "vmadot"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("kernel: vmadot"), "got: {text}");
    assert!(text.contains("vmadot"), "no match report: {text}");
    assert!(text.contains("isax"), "no intrinsic in lowered program: {text}");
}

#[test]
fn compile_opt_level_2_succeeds_and_opt_level_0_is_identity() {
    let out = aquas(&["compile", "vmadot", "--opt-level", "2"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("kernel: vmadot"), "got: {text}");
    assert!(text.contains("isax"), "no intrinsic in optimized lowered program: {text}");
    // -O0 must be byte-identical to the default compile output.
    let plain = aquas(&["compile", "vmadot"]);
    let o0 = aquas(&["compile", "vmadot", "--opt-level", "0"]);
    assert!(o0.status.success(), "stderr: {}", String::from_utf8_lossy(&o0.stderr));
    assert_eq!(plain.stdout, o0.stdout, "--opt-level 0 changed the compile output");
}

#[test]
fn compile_rejects_bad_opt_level() {
    let out = aquas(&["compile", "vmadot", "--opt-level", "3"]);
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("opt level"), "stderr: {err}");
}

#[test]
fn opt_demo_shows_pipeline_and_agrees() {
    let out = aquas(&["opt", "--demo"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("pipeline:"), "no pipeline stats line: {text}");
    assert!(text.contains("dynamic ops"), "no dynamic-op delta line: {text}");
    assert!(text.contains("identical"), "demo run did not verify equivalence: {text}");
    assert!(!text.contains("DIVERGED"), "demo run diverged: {text}");
}

#[test]
fn compile_unknown_kernel_fails() {
    let out = aquas(&["compile", "nonexistent_kernel"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown kernel"));
}

#[test]
fn serve_runs_artifact_free() {
    // The runtime falls back to the built-in simulated manifest, so
    // `aquas serve` must work on a clean checkout.
    let out = aquas(&["serve", "-n", "2"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("platform:"), "no platform line: {text}");
    assert!(text.contains("req 0:"), "no request metrics: {text}");
    assert!(text.contains("req 1:"), "second request missing: {text}");
    assert!(text.contains("leak-free true"), "KV accounting line missing: {text}");
}

#[test]
fn serve_trace_smoke() {
    let out = aquas(&[
        "serve", "--trace", "n=4,seed=11,rate=4,plen=4..8,gen=3..6", "--batch", "4",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for req in ["req 0:", "req 1:", "req 2:", "req 3:"] {
        assert!(text.contains(req), "missing {req}: {text}");
    }
    assert!(text.contains("total: 4 requests"), "no aggregate line: {text}");
    assert!(text.contains("leak-free true"), "KV leak check failed or missing: {text}");
}

#[test]
fn serve_trace_replay_is_deterministic() {
    // Two replays of the same trace spec must produce byte-identical
    // output: same token streams and same simulated-clock metrics (the
    // serve path prints nothing host-wall-clock-dependent).
    let args = [
        "serve", "--trace", "n=6,seed=3,rate=2,plen=4..10,gen=4..8", "--batch", "4",
        "--policy", "fair",
    ];
    let a = aquas(&args);
    let b = aquas(&args);
    assert!(a.status.success(), "stderr: {}", String::from_utf8_lossy(&a.stderr));
    assert_eq!(a.stdout, b.stdout, "trace replay diverged between runs");
    assert_eq!(a.stderr, b.stderr);
}

#[test]
fn serve_multicore_trace_smoke() {
    let out = aquas(&[
        "serve", "--cores", "2", "--trace",
        "n=6,seed=11,rate=8,plen=4..8,gen=3..6,burst=3,tail=0.25,mix=0.5",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for req in ["req 0:", "req 1:", "req 5:"] {
        assert!(text.contains(req), "missing {req}: {text}");
    }
    assert!(text.contains("2 cores x batch 4"), "no SoC aggregate line: {text}");
    assert!(text.contains("soc: migrations"), "no SoC counter line: {text}");
    assert!(text.contains("core 0 kv:"), "no core-0 shard line: {text}");
    assert!(text.contains("core 1 kv:"), "no core-1 shard line: {text}");
    assert!(!text.contains("leak-free false"), "a shard leaked: {text}");
}

#[test]
fn serve_multicore_replay_is_deterministic() {
    let args = [
        "serve", "--cores", "4", "--trace",
        "n=8,seed=5,rate=16,plen=4..10,gen=4..8,burst=4,tail=0.2,mix=0.5",
    ];
    let a = aquas(&args);
    let b = aquas(&args);
    assert!(a.status.success(), "stderr: {}", String::from_utf8_lossy(&a.stderr));
    assert_eq!(a.stdout, b.stdout, "SoC trace replay diverged between runs");
    assert_eq!(a.stderr, b.stderr);
}

#[test]
fn serve_rejects_bad_trace_spec() {
    let out = aquas(&["serve", "--trace", "n=0"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("trace spec"));
}

#[test]
fn serve_chaos_replay_is_byte_identical() {
    // A seeded fault schedule — core death plus DMA error injection — is
    // part of the deterministic replay contract: two runs of the same
    // spec print the same bytes, including the fault counters.
    let args = [
        "serve", "--cores", "4", "--trace", "n=8,seed=5,rate=12,plen=4..8,gen=3..6",
        "--faults", "coredown=1@0,dmaerr=0.05,seed=3",
    ];
    let a = aquas(&args);
    let b = aquas(&args);
    assert!(a.status.success(), "stderr: {}", String::from_utf8_lossy(&a.stderr));
    let text = String::from_utf8_lossy(&a.stdout);
    assert!(text.contains("faults: injected"), "no fault counter line: {text}");
    assert!(!text.contains("leak-free false"), "a shard leaked under chaos: {text}");
    assert_eq!(a.stdout, b.stdout, "chaos replay diverged between runs");
    assert_eq!(a.stderr, b.stderr);
}

#[test]
fn serve_faults_forces_the_soc_path_on_one_core() {
    // `--faults` routes through the SoC coordinator even without
    // `--cores`, so a lone core still gets the injection machinery (and
    // the SoC-format report).
    let out = aquas(&["serve", "-n", "2", "--faults", "dmaerr=0.1,seed=7"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("1 cores x batch"), "not on the SoC path: {text}");
    assert!(text.contains("faults: injected"), "no fault counter line: {text}");
}

#[test]
fn explore_demo_prints_frontier() {
    // A trimmed 4-point sub-space keeps the debug-build smoke fast while
    // exercising the full search path (oracle, baselines, frontier).
    let out = aquas(&[
        "explore", "--demo", "--space", "width=4|8,burst=1|8,inflight=2,banks=2,unroll=1",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("aquas explore"), "no summary header: {text}");
    assert!(text.contains("Pareto frontier"), "no frontier table: {text}");
    assert!(text.contains("mutually non-dominated: yes"), "property line missing: {text}");
    assert!(
        text.contains("covers hand-picked Sec 6.1 configs: yes"),
        "coverage line missing: {text}"
    );
    assert!(text.contains("e-graph offload proof"), "no offload proof lines: {text}");
    assert!(text.contains("best point"), "no best-point line: {text}");
}

#[test]
fn explore_replay_is_deterministic() {
    let args = [
        "explore", "--demo", "--space", "width=4|8,burst=8,inflight=1|2,banks=2,unroll=1",
        "--seed", "7",
    ];
    let a = aquas(&args);
    let b = aquas(&args);
    assert!(a.status.success(), "stderr: {}", String::from_utf8_lossy(&a.stderr));
    assert_eq!(a.stdout, b.stdout, "explore replay diverged between runs");
}

#[test]
fn explore_rejects_bad_space_spec() {
    let out = aquas(&["explore", "--demo", "--space", "width=0"]);
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("explore space"), "stderr: {err}");
    // Unknown axis and malformed seed are equally diagnostic.
    let out = aquas(&["explore", "--demo", "--space", "frobnicate=4"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("explore space"));
    let out = aquas(&["explore", "--demo", "--seed", "banana"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("seed"));
}

#[test]
fn serve_rejects_bad_fault_spec() {
    // Missing `@` in a coredown event: a diagnostic parse error before
    // anything runs, never a panic or a silent default.
    let out = aquas(&["serve", "--faults", "coredown=9"]);
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fault spec"), "stderr: {err}");
    let out = aquas(&["serve", "--faults", "blastradius=1"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("fault spec"));
}
