//! Multi-core SoC serving integration: sharded KV migration, work
//! stealing, mass-queue draining and the 1-core bitwise guarantee.
//!
//! Works on a clean checkout (simulated-manifest fallback), like
//! `runtime_integration.rs`. The scheduling properties checked here are
//! the serving-layer invariants `docs/serving.md` documents: moving a
//! sequence between cores (migration or stealing) may change *when* it
//! runs but never *what* it generates, and every shard's block
//! accounting returns to empty once the trace drains.

use aquas::coordinator::{
    Coordinator, CoordinatorConfig, DispatchPolicy, PagedKvConfig, SchedulePolicy, SocConfig,
    SocCoordinator, TraceRequest, TraceSpec,
};
use aquas::runtime::Runtime;

fn runtime() -> Runtime {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Runtime::load(&dir).expect("runtime load (simulated fallback) cannot fail")
}

fn request(prompt_len: usize, max_new: usize, seed: u64, vocab: usize) -> TraceRequest {
    let mut rng = aquas::util::rng::Rng::new(seed);
    let prompt: Vec<i32> = (0..prompt_len).map(|_| rng.below(vocab as u64) as i32).collect();
    TraceRequest { arrive_ms: 0.0, prompt, max_new_tokens: max_new, slo_factor: 1.0 }
}

/// Replay the same requests through a plain single engine with an ample
/// KV pool and return the per-id token streams — the ground truth any
/// SoC schedule must reproduce bitwise.
fn ample_1core_tokens(rt: &Runtime, reqs: &[TraceRequest]) -> Vec<(u64, Vec<i32>)> {
    let mut c = Coordinator::new(rt, CoordinatorConfig::default());
    c.submit_trace(reqs).expect("1-core submit");
    let metrics = c.run_to_completion().expect("1-core replay");
    metrics.iter().map(|m| (m.id, m.generated.clone())).collect()
}

#[test]
fn migration_storm_preserves_tokens_and_leaks_nothing() {
    let rt = runtime();
    let vocab = rt.manifest().model.vocab;
    // Tiny 6-block shards (4 slots each). Even-index requests need 5
    // blocks — a shard fits exactly one — while odd-index requests are
    // one-block quickies. Round-robin dispatch pins the big ones to
    // core 0, so its shard runs dry with work queued while core 1
    // drains and frees its whole shard: the dry-shard migration path
    // must carry core 0's queue over, one sequence at a time.
    let reqs: Vec<TraceRequest> = (0..8)
        .map(|i| {
            if i % 2 == 0 {
                request(8, 12, 100 + i, vocab)
            } else {
                request(2, 2, 100 + i, vocab)
            }
        })
        .collect();
    let per_core = CoordinatorConfig {
        max_active: 2,
        kv: PagedKvConfig { block_slots: 4, num_blocks: 6 },
        ..Default::default()
    };
    let mut soc = SocCoordinator::new(
        &rt,
        SocConfig {
            cores: 2,
            per_core,
            dispatch: DispatchPolicy::RoundRobin,
            ..Default::default()
        },
    );
    soc.submit_trace(&reqs).expect("soc submit");
    let metrics = soc.run_to_completion().expect("soc replay");
    let stats = soc.stats();
    assert_eq!(metrics.len(), reqs.len());
    assert!(stats.migrations > 0, "storm never exercised migration: {stats:?}");
    for (k, kv) in stats.per_core_kv.iter().enumerate() {
        assert!(kv.leak_free(), "core {k} shard leaked under migration: {kv:?}");
    }
    // Migration moves *where* a sequence decodes, never *what* it
    // decodes: token streams match an ample single-engine replay
    // bitwise, id by id.
    let got: Vec<(u64, Vec<i32>)> =
        metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
    assert_eq!(got, ample_1core_tokens(&rt, &reqs), "migration perturbed token streams");
}

#[test]
fn drained_core_steals_work_and_tokens_survive() {
    let rt = runtime();
    let vocab = rt.manifest().model.vocab;
    // Round-robin lands three long jobs on core 0 and three quickies on
    // core 1; with one active slot per core, core 1 drains while core 0
    // still has two queued — the steal path must raid core 0's queue
    // (its depth-2 tail) instead of idling.
    let reqs: Vec<TraceRequest> = (0..6)
        .map(|i| {
            if i % 2 == 0 {
                request(6, 24, 200 + i, vocab)
            } else {
                request(2, 1, 200 + i, vocab)
            }
        })
        .collect();
    let per_core = CoordinatorConfig { max_active: 1, ..Default::default() };
    let mut soc = SocCoordinator::new(
        &rt,
        SocConfig {
            cores: 2,
            per_core,
            dispatch: DispatchPolicy::RoundRobin,
            ..Default::default()
        },
    );
    soc.submit_trace(&reqs).expect("soc submit");
    let metrics = soc.run_to_completion().expect("soc replay");
    let stats = soc.stats();
    assert!(stats.steals > 0, "drained core never stole: {stats:?}");
    for kv in &stats.per_core_kv {
        assert!(kv.leak_free(), "shard leaked under stealing: {kv:?}");
    }
    let got: Vec<(u64, Vec<i32>)> =
        metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
    assert_eq!(got, ample_1core_tokens(&rt, &reqs), "stealing perturbed token streams");
}

#[test]
fn thousands_of_queued_sequences_drain_leak_free() {
    let rt = runtime();
    let model = rt.manifest().model.clone();
    // 1500 tiny sequences, all arriving at t = 0, across 4 shards: the
    // async-admission queues must drain completely with exact block
    // accounting on every shard and one metric per SoC-wide id.
    let spec = TraceSpec {
        n: 1500,
        seed: 13,
        rate: 0.0,
        plen: (2, 4),
        gen: (1, 2),
        ..Default::default()
    };
    let reqs = spec.generate_capped(model.vocab, model.prefill_len, model.max_seq);
    let mut soc = SocCoordinator::new(&rt, SocConfig { cores: 4, ..Default::default() });
    soc.submit_trace(&reqs).expect("soc submit");
    let metrics = soc.run_to_completion().expect("soc replay");
    assert_eq!(metrics.len(), 1500);
    for (i, m) in metrics.iter().enumerate() {
        assert_eq!(m.id, i as u64, "metrics not dense in SoC id space");
        assert_eq!(
            m.generated.len(),
            reqs[i].max_new_tokens,
            "request {i} retired early"
        );
    }
    let stats = soc.stats();
    assert_eq!(stats.cores, 4);
    for (k, kv) in stats.per_core_kv.iter().enumerate() {
        assert!(kv.leak_free(), "core {k} shard leaked after drain: {kv:?}");
    }
}

#[test]
fn one_core_soc_is_bitwise_the_plain_engine() {
    let rt = runtime();
    let model = rt.manifest().model.clone();
    // Heavy-tailed bursty trace with a mixed SLO population, replayed
    // through the Fair (EDF) policy both ways: the 1-core SoC must be
    // the plain engine bitwise — ids, tokens, TTFT/ITL and the clock.
    let spec = TraceSpec {
        n: 12,
        seed: 21,
        rate: 4.0,
        plen: (4, 10),
        gen: (4, 8),
        burst: 2.0,
        tail: 0.2,
        mix: 0.5,
    };
    let reqs = spec.generate_capped(model.vocab, model.prefill_len, model.max_seq);
    let cfg = CoordinatorConfig { policy: SchedulePolicy::Fair, ..Default::default() };

    let mut plain = Coordinator::new(&rt, cfg.clone());
    plain.submit_trace(&reqs).expect("plain submit");
    let pm = plain.run_to_completion().expect("plain replay");

    let mut soc =
        SocCoordinator::new(&rt, SocConfig { cores: 1, per_core: cfg, ..Default::default() });
    soc.submit_trace(&reqs).expect("soc submit");
    let sm = soc.run_to_completion().expect("soc replay");

    assert_eq!(soc.sim_elapsed_ms(), plain.sim_now_ms(), "clocks diverged");
    assert_eq!(sm.len(), pm.len());
    for (s, p) in sm.iter().zip(&pm) {
        assert_eq!(s.id, p.id);
        assert_eq!(s.generated, p.generated, "req {} tokens diverged", s.id);
        assert_eq!(s.ttft_us, p.ttft_us, "req {} TTFT diverged", s.id);
        assert_eq!(s.itl_us, p.itl_us, "req {} ITL diverged", s.id);
        assert_eq!(s.preemptions, p.preemptions, "req {} preemptions diverged", s.id);
    }
    let stats = soc.stats();
    assert_eq!(stats.migrations, 0);
    assert_eq!(stats.steals, 0);
    assert_eq!(stats.contention_dma_cycles, 0.0, "a lone core cannot contend");
}
