//! Randomized stress tests for the worklist e-graph engine (seeded, fully
//! deterministic — the in-crate PRNG replaces proptest on the offline
//! image).
//!
//! Invariants checked after every rebuild:
//! - `class_ids` returns canonical ids; node/class counts are consistent;
//! - stored nodes are canonical and **congruence-closed**: no two live
//!   classes contain the same (sym, canonical-children) shape;
//! - hashcons idempotence: re-adding any stored node lands in its class;
//! - the symbol occurrence index covers every (class, sym) occurrence.
//!
//! Plus an engine-equivalence check for the compiler: `match_isax` must
//! produce the same `CompileStats` outcomes run-to-run (the pre-PR engine
//! iterated `HashMap`s and was not deterministic) and must still match
//! every bundled workload kernel and variant.

use std::collections::HashMap;

use aquas::egraph::{ClassId, EGraph, ENode};
use aquas::util::rng::Rng;

fn check_invariants(g: &mut EGraph) {
    let classes = g.class_ids();
    let mut total = 0usize;
    for &c in &classes {
        assert_eq!(g.find(c), c, "class_ids returns canonical ids");
        total += g.nodes(c).len();
    }
    assert_eq!(total, g.node_count(), "node_count matches stored nodes");
    assert_eq!(classes.len(), g.class_count(), "class_count matches live classes");

    // Congruence closure: one class per canonical node shape.
    let mut shapes: HashMap<(u32, Vec<u32>), ClassId> = HashMap::new();
    for &c in &classes {
        for n in g.nodes(c) {
            for &ch in &n.children {
                assert_eq!(g.find(ch), ch, "post-rebuild children are canonical");
            }
            let key = (n.sym.0, n.children.iter().map(|k| k.0).collect::<Vec<u32>>());
            match shapes.get(&key) {
                Some(&prev) => assert_eq!(
                    prev, c,
                    "congruent nodes live in distinct classes: sym {}",
                    g.sym_name(n.sym)
                ),
                None => {
                    shapes.insert(key, c);
                }
            }
            assert!(
                g.classes_with_sym(n.sym).contains(&c),
                "symbol index misses class {c:?} for sym {}",
                g.sym_name(n.sym)
            );
        }
    }

    // Hashcons idempotence.
    let mut all: Vec<(ClassId, ENode)> = Vec::new();
    for &c in &classes {
        for n in g.nodes(c) {
            all.push((c, n.clone()));
        }
    }
    let before = g.node_count();
    for (c, n) in all {
        let got = g.add(n);
        assert_eq!(g.find(got), c, "re-adding a stored node lands in its class");
    }
    assert_eq!(g.node_count(), before, "re-adds create no nodes");
}

#[test]
fn stress_random_graphs_hold_invariants() {
    let mut rng = Rng::new(0xE64AF1);
    for round in 0..4 {
        let mut g = EGraph::new();
        let mut ids: Vec<ClassId> =
            (0..16).map(|i| g.add_named(&format!("leaf{i}"), vec![])).collect();
        let sym_pool: Vec<String> = (0..12).map(|i| format!("op{i}")).collect();
        for step in 0..1200 {
            match rng.range(0, 10) {
                // ~70% adds: random symbol over random existing classes.
                0..=6 => {
                    let arity = rng.range(0, 4);
                    let kids: Vec<ClassId> =
                        (0..arity).map(|_| *rng.choose(&ids)).collect();
                    let name = rng.choose(&sym_pool).clone();
                    ids.push(g.add_named(&name, kids));
                }
                // ~20% random unions.
                7 | 8 => {
                    let a = *rng.choose(&ids);
                    let b = *rng.choose(&ids);
                    g.union(a, b);
                }
                // ~10% rebuilds at arbitrary points.
                _ => g.rebuild(),
            }
            if step % 400 == 399 {
                g.rebuild();
                check_invariants(&mut g);
            }
        }
        g.rebuild();
        check_invariants(&mut g);
        assert!(g.node_count() > 300, "round {round}: graph stayed trivial");
    }
}

#[test]
fn stress_union_heavy_collapse() {
    // Aggressively union everything in sight: the graph must collapse
    // without violating congruence, and repeated rebuilds must be no-ops.
    let mut rng = Rng::new(0xC0117);
    let mut g = EGraph::new();
    let mut ids: Vec<ClassId> =
        (0..8).map(|i| g.add_named(&format!("x{i}"), vec![])).collect();
    for _ in 0..400 {
        let a = *rng.choose(&ids);
        let b = *rng.choose(&ids);
        let f = g.add_named("f", vec![a, b]);
        ids.push(f);
        let c = *rng.choose(&ids);
        g.union(f, c);
    }
    g.rebuild();
    check_invariants(&mut g);
    let count = g.node_count();
    let class_count = g.class_count();
    g.rebuild(); // idempotent
    assert_eq!(g.node_count(), count);
    assert_eq!(g.class_count(), class_count);
}

#[test]
fn match_isax_outcomes_deterministic_on_bundled_workloads() {
    let opts = aquas::compiler::CompileOptions::default();
    for k in aquas::workloads::table2_kernels() {
        let r1 = aquas::compiler::compile(&k.software, &[k.isax.clone()], &opts).unwrap();
        assert!(
            r1.stats.matched.contains(&k.isax.name),
            "{}: canonical software must match: {:?}",
            k.name,
            r1.stats
        );
        let r2 = aquas::compiler::compile(&k.software, &[k.isax.clone()], &opts).unwrap();
        assert_eq!(
            r1.stats, r2.stats,
            "{}: CompileStats must be deterministic run-to-run",
            k.name
        );
        for (desc, variant) in &k.variants {
            let rv = aquas::compiler::compile(variant, &[k.isax.clone()], &opts).unwrap();
            assert!(
                rv.stats.matched.contains(&k.isax.name),
                "{} variant `{desc}` must match: {:?}",
                k.name,
                rv.stats
            );
        }
    }
}
