//! Property-based tests (hand-rolled generator loop over the seeded
//! in-crate PRNG — proptest is not in the offline vendor set).
//!
//! Invariants covered:
//! - interface model: decomposition always reconstructs the request and
//!   respects legality/alignment; latency recurrences are monotone;
//! - scheduling: per-interface `after` chains are acyclic + complete, and
//!   the memoized order never loses to FIFO;
//! - e-graph: union/find algebra, hashcons idempotence, rewrites never
//!   break congruence;
//! - coordinator: KV cursor bookkeeping under random admission sequences.

use aquas::interface::latency::{sequence_latency, TransactionKind};
use aquas::interface::model::{InterfaceSet, MemInterface};
use aquas::util::rng::Rng;

const CASES: usize = 200;

#[test]
fn prop_decompose_reconstructs_and_is_legal() {
    let mut rng = Rng::new(0xDEC0);
    for case in 0..CASES {
        let itfc = random_itfc(&mut rng);
        let size = rng.range(1, 4096);
        // Base addresses are width-aligned (buffers are placed that way by
        // the builder); sub-width misalignment is the hardware fallback
        // path, not the canonicalizer's job.
        let addr = (rng.range(0, 1024) * itfc.width) as u64;
        let parts = itfc.decompose(addr, size);
        assert_eq!(parts.iter().sum::<usize>(), size, "case {case}");
        let mut a = addr;
        for (i, &m) in parts.iter().enumerate() {
            if m >= itfc.width {
                assert!(itfc.is_legal(a, m), "case {case} part {i}: {m}B at {a} on {itfc:?}");
            }
            a += m as u64;
        }
    }
}

#[test]
fn prop_latency_monotone_in_size_and_count() {
    let mut rng = Rng::new(0x1A7);
    for case in 0..CASES {
        let itfc = random_itfc(&mut rng);
        let n = rng.range(1, 12);
        let sizes: Vec<usize> =
            (0..n).map(|_| itfc.width << rng.range(0, 3).min(usize::BITS as usize)).collect();
        let sizes: Vec<usize> =
            sizes.into_iter().map(|s| s.min(itfc.max_transaction())).collect();
        for kind in [TransactionKind::Load, TransactionKind::Store] {
            let full = sequence_latency(&itfc, kind, &sizes);
            let prefix = sequence_latency(&itfc, kind, &sizes[..sizes.len() - 1]);
            assert!(full >= prefix, "case {case}: adding a transaction reduced latency");
        }
    }
}

/// Draw a uniform-size legal transaction sequence on `itfc`.
fn uniform_sizes(rng: &mut Rng, itfc: &MemInterface, n: usize) -> Vec<usize> {
    let max_shift = itfc.max_beats.trailing_zeros() as usize + 1;
    let beats = 1usize << rng.range(0, max_shift);
    vec![itfc.width * beats; n]
}

#[test]
fn prop_latency_monotone_in_transaction_size() {
    // Growing any single transaction must never reduce sequence latency.
    let mut rng = Rng::new(0x512E);
    for case in 0..CASES {
        let itfc = random_itfc(&mut rng);
        let n = rng.range(1, 12);
        let max_shift = itfc.max_beats.trailing_zeros() as usize + 1;
        let sizes: Vec<usize> =
            (0..n).map(|_| itfc.width << rng.range(0, max_shift)).collect();
        let j = rng.range(0, n);
        let mut grown = sizes.clone();
        grown[j] = (grown[j] * 2).min(itfc.max_transaction());
        for kind in [TransactionKind::Load, TransactionKind::Store] {
            let before = sequence_latency(&itfc, kind, &sizes);
            let after = sequence_latency(&itfc, kind, &grown);
            assert!(
                after >= before,
                "case {case} {kind:?}: growing txn {j} reduced latency {before} -> {after}"
            );
        }
    }
}

#[test]
fn prop_completion_cycles_end_at_sequence_latency() {
    use aquas::interface::latency::completion_cycles;
    let mut rng = Rng::new(0xC0C0);
    for case in 0..CASES {
        let itfc = random_itfc(&mut rng);
        let n = rng.range(1, 16);
        let max_shift = itfc.max_beats.trailing_zeros() as usize + 1;
        let sizes: Vec<usize> =
            (0..n).map(|_| itfc.width << rng.range(0, max_shift)).collect();
        for kind in [TransactionKind::Load, TransactionKind::Store] {
            let cs = completion_cycles(&itfc, kind, &sizes);
            assert_eq!(cs.len(), n, "case {case}");
            assert!(
                cs.windows(2).all(|w| w[0] < w[1]),
                "case {case} {kind:?}: completions not strictly increasing: {cs:?}"
            );
            assert_eq!(
                *cs.last().unwrap(),
                sequence_latency(&itfc, kind, &sizes),
                "case {case} {kind:?}: last completion != sequence latency"
            );
        }
    }
}

#[test]
fn prop_tk_store_form_is_exact_on_uniform_sequences() {
    // §4.3 documented bound, store half: the closed form reproduces the
    // exact recurrence for back-to-back same-size stores.
    use aquas::interface::latency::tk_estimate;
    let mut rng = Rng::new(0x7E57);
    for case in 0..CASES {
        let itfc = random_itfc(&mut rng);
        let n = rng.range(8, 33);
        let sizes = uniform_sizes(&mut rng, &itfc, n);
        let exact = sequence_latency(&itfc, TransactionKind::Store, &sizes) as f64;
        let est = tk_estimate(&itfc, TransactionKind::Store, &[sizes.clone()]);
        assert!(
            (est - exact).abs() < 1e-9,
            "case {case}: store T_k {est} != exact {exact} on {itfc:?} x{}",
            sizes.len()
        );
    }
}

#[test]
fn prop_tk_load_form_within_documented_error_bound() {
    // §4.3 documented bound, load half: within 50% of the exact
    // recurrence (the closed form drops the per-transaction issue cycle;
    // see the `tk_estimate` docs). Anything past that means the
    // approximation or the recurrence drifted.
    use aquas::interface::latency::tk_estimate;
    let mut rng = Rng::new(0x7E58);
    for case in 0..CASES {
        let itfc = random_itfc(&mut rng);
        let n = rng.range(8, 33);
        let sizes = uniform_sizes(&mut rng, &itfc, n);
        let exact = sequence_latency(&itfc, TransactionKind::Load, &sizes) as f64;
        let est = tk_estimate(&itfc, TransactionKind::Load, &[sizes.clone()]);
        let rel = (est - exact).abs() / exact.max(1.0);
        assert!(
            rel <= 0.5,
            "case {case}: load T_k {est} vs exact {exact} (rel {rel:.3}) on {itfc:?} x{}",
            sizes.len()
        );
    }
}

#[test]
fn prop_dmasim_single_stream_matches_recurrence_exactly() {
    // The event-driven burst engine degenerates to the exact §4.1
    // recurrence on any single uncontended stream — stores and loads.
    use aquas::interface::dmasim;
    let mut rng = Rng::new(0xD3A5);
    for case in 0..CASES {
        let itfc = random_itfc(&mut rng);
        let n = rng.range(1, 33);
        let sizes = uniform_sizes(&mut rng, &itfc, n);
        for kind in [TransactionKind::Load, TransactionKind::Store] {
            let sim = dmasim::simulate_sizes(&itfc, kind, &sizes);
            let exact = sequence_latency(&itfc, kind, &sizes);
            assert_eq!(
                sim, exact,
                "case {case} {kind:?}: simulator {sim} != recurrence {exact} on {itfc:?} x{n}"
            );
        }
    }
}

#[test]
fn prop_dmasim_makes_tk_bound_executable() {
    // The §4.3 documented T_k error bound, measured against the
    // *simulator* instead of the recurrence it was derived from: the
    // store form must reproduce the simulated cycles exactly on uniform
    // single streams, the load form must stay within 50%.
    use aquas::interface::dmasim;
    use aquas::interface::latency::tk_estimate;
    let mut rng = Rng::new(0xD3A6);
    for case in 0..CASES {
        let itfc = random_itfc(&mut rng);
        let n = rng.range(8, 33);
        let sizes = uniform_sizes(&mut rng, &itfc, n);
        let st = dmasim::simulate_sizes(&itfc, TransactionKind::Store, &sizes) as f64;
        let st_est = tk_estimate(&itfc, TransactionKind::Store, &[sizes.clone()]);
        assert!(
            (st_est - st).abs() < 1e-9,
            "case {case}: store T_k {st_est} != simulated {st} on {itfc:?}"
        );
        let ld = dmasim::simulate_sizes(&itfc, TransactionKind::Load, &sizes) as f64;
        let ld_est = tk_estimate(&itfc, TransactionKind::Load, &[sizes.clone()]);
        let rel = (ld_est - ld).abs() / ld.max(1.0);
        assert!(
            rel <= 0.5,
            "case {case}: load T_k {ld_est} vs simulated {ld} (rel {rel:.3}) on {itfc:?}"
        );
    }
}

#[test]
fn prop_dmasim_bank_conflicts_only_add_cycles() {
    // Random two-interface traces into one scratchpad: fewer banks can
    // only delay completions, never accelerate them, and enough banks
    // (one per interface) are always conflict-free.
    use aquas::interface::dmasim::{simulate_txns, SimTxn, SramSpec};
    use aquas::interface::model::InterfaceId;
    let mut rng = Rng::new(0xBA2C);
    for case in 0..60 {
        let set = InterfaceSet::rocket_default();
        let n = rng.range(2, 12);
        let txns: Vec<SimTxn> = (0..n)
            .map(|i| {
                let k = rng.range(0, 2);
                let itfc = set.get(InterfaceId(k));
                let max_shift = itfc.max_beats.trailing_zeros() as usize + 1;
                let size = itfc.width << rng.range(0, max_shift);
                SimTxn {
                    op: i,
                    itfc: InterfaceId(k),
                    kind: if rng.bool(0.3) {
                        TransactionKind::Store
                    } else {
                        TransactionKind::Load
                    },
                    addr: (i * 64) as u64,
                    size,
                    sram: Some(0),
                }
            })
            .collect();
        let run = |banks: usize| {
            let srams = [SramSpec { name: "s".into(), banks }];
            simulate_txns(&set, &srams, &txns).unwrap()
        };
        let one = run(1);
        let two = run(2);
        assert_eq!(two.conflict_cycles, 0, "case {case}: one bank per interface conflicted");
        assert!(one.makespan >= two.makespan, "case {case}: contention sped things up");
        // Conflicts may reorder dispatch, so compare completions per op.
        let tight: std::collections::HashMap<usize, u64> =
            one.txns.iter().map(|t| (t.op, t.complete)).collect();
        for t in &two.txns {
            assert!(
                tight[&t.op] >= t.complete,
                "case {case}: op {} completed earlier under contention",
                t.op
            );
        }
    }
}

#[test]
fn prop_schedule_beats_or_matches_fifo() {
    use aquas::synthesis::scheduling::mixed_sequence_latency;
    let mut rng = Rng::new(0x5EDB);
    for case in 0..100 {
        let itfc = MemInterface::system_bus();
        let n = rng.range(2, 6);
        let units: Vec<Vec<usize>> = (0..n)
            .map(|_| {
                let segs = rng.range(1, 4);
                (0..segs).map(|_| itfc.width << rng.range(0, 4)).map(|s| s.min(64)).collect()
            })
            .collect();
        // FIFO latency
        let fifo: Vec<(TransactionKind, usize)> = units
            .iter()
            .flat_map(|u| u.iter().map(|&s| (TransactionKind::Load, s)))
            .collect();
        let fifo_lat = mixed_sequence_latency(&itfc, &fifo);
        // Best permutation (exhaustive for tiny n) must be <= FIFO.
        let mut best = u64::MAX;
        let mut order: Vec<usize> = (0..n).collect();
        permute(&mut order, 0, &mut |perm| {
            let seq: Vec<(TransactionKind, usize)> = perm
                .iter()
                .flat_map(|&i| units[i].iter().map(|&s| (TransactionKind::Load, s)))
                .collect();
            best = best.min(mixed_sequence_latency(&itfc, &seq));
        });
        assert!(best <= fifo_lat, "case {case}");
    }
}

fn permute(items: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
    if k == items.len() {
        f(items);
        return;
    }
    for i in k..items.len() {
        items.swap(k, i);
        permute(items, k + 1, f);
        items.swap(k, i);
    }
}

#[test]
fn prop_egraph_union_find_algebra() {
    use aquas::egraph::EGraph;
    let mut rng = Rng::new(0xE6);
    for _case in 0..50 {
        let mut g = EGraph::new();
        let leaves: Vec<_> = (0..10).map(|i| g.add_named(&format!("x{i}"), vec![])).collect();
        // random unions
        for _ in 0..8 {
            let a = *rng.choose(&leaves);
            let b = *rng.choose(&leaves);
            g.union(a, b);
        }
        g.rebuild();
        // find is idempotent + class-consistent
        for &l in &leaves {
            let r = g.find(l);
            assert_eq!(g.find(r), r);
        }
        // congruence: f(a) == f(b) whenever a == b
        for _ in 0..10 {
            let a = *rng.choose(&leaves);
            let b = *rng.choose(&leaves);
            let fa = g.add_named("f", vec![a]);
            let fb = g.add_named("f", vec![b]);
            g.rebuild();
            if g.find(a) == g.find(b) {
                assert_eq!(g.find(fa), g.find(fb));
            }
        }
    }
}

#[test]
fn prop_rewrites_preserve_interpreter_semantics() {
    // Random affine index expressions rewritten by the internal rules must
    // evaluate identically: extract the cheapest form and compare.
    use aquas::compiler::rules::{affine_cost, internal_rules};
    use aquas::egraph::{extract_best, EGraph, Runner};
    let mut rng = Rng::new(0x5EAA);
    for case in 0..60 {
        let iv = rng.range(0, 16) as i64;
        let c1 = rng.range(1, 5) as i64;
        let shift = rng.range(0, 4) as i64;
        // expr: (iv + c1) << shift
        let expected = (iv + c1) << shift;

        let mut g = EGraph::new();
        let ivc = g.add_named("ivval", vec![]);
        let c1c = g.add_named(&format!("const:{c1}"), vec![]);
        let add = g.add_named("add", vec![ivc, c1c]);
        let sh = g.add_named(&format!("const:{shift}"), vec![]);
        let root = g.add_named("shl", vec![add, sh]);
        Runner::default().run(&mut g, &internal_rules());
        let term = extract_best(&g, root, &affine_cost).unwrap();
        let got = eval(&term, iv);
        assert_eq!(got, expected, "case {case}: {}", term.to_sexp());
    }
}

fn eval(t: &aquas::egraph::Extracted, iv: i64) -> i64 {
    if t.sym == "ivval" {
        return iv;
    }
    if let Some(c) = t.sym.strip_prefix("const:") {
        return c.parse().unwrap();
    }
    let kids: Vec<i64> = t.children.iter().map(|k| eval(k, iv)).collect();
    match t.sym.as_str() {
        "add" => kids[0] + kids[1],
        "sub" => kids[0] - kids[1],
        "mul" => kids[0] * kids[1],
        "div" => kids[0] / kids[1],
        "rem" => kids[0] % kids[1],
        "shl" => kids[0] << kids[1],
        "shr" => kids[0] >> kids[1],
        "and" => kids[0] & kids[1],
        "or" => kids[0] | kids[1],
        "xor" => kids[0] ^ kids[1],
        other => panic!("unexpected symbol {other}"),
    }
}

#[test]
fn prop_loop_passes_preserve_semantics_on_random_programs() {
    use aquas::compiler::loop_passes::{apply, LoopPass};
    use aquas::compiler::matcher::top_loops;
    use aquas::interface::cache::CacheHint;
    use aquas::ir::builder::FuncBuilder;
    use aquas::ir::interp::{run as interp, Memory};
    use aquas::runtime::DType;

    let mut rng = Rng::new(0x100F);
    for case in 0..40 {
        let n = *rng.choose(&[8i64, 16, 24, 32]);
        let mulk = rng.range(1, 5) as i64;
        let addk = rng.range(0, 9) as i64;
        let mut b = FuncBuilder::new("rand");
        let x = b.global("x", DType::I32, n as usize, CacheHint::Unknown);
        let y = b.global("y", DType::I32, n as usize, CacheHint::Unknown);
        b.for_range(0, n, 1, |b, iv| {
            let v = b.load(x, iv);
            let k = b.const_i(mulk);
            let m = b.mul(v, k);
            let a = b.const_i(addk);
            let w = b.add(m, a);
            b.store(y, iv, w);
        });
        let f = b.finish(&[]);
        let target = top_loops(&f)[0];

        let data: Vec<i32> = (0..n as i32).map(|i| i * 3 - 7).collect();
        let run_one = |func: &aquas::ir::Func| {
            let mut mem = Memory::for_func(func);
            mem.write_i32(aquas::ir::func::BufferId(0), &data);
            interp(func, &[], &mut mem).unwrap();
            mem.read_i32(aquas::ir::func::BufferId(1))
        };
        let want = run_one(&f);

        for pass in [LoopPass::Unroll(2), LoopPass::Tile(4), LoopPass::Unroll(4)] {
            if let Ok(g) = apply(&f, target, pass) {
                aquas::ir::verifier::verify(&g)
                    .unwrap_or_else(|e| panic!("case {case} {pass}: {e}"));
                assert_eq!(run_one(&g), want, "case {case} {pass}");
            }
        }
    }
}

fn random_itfc(rng: &mut Rng) -> MemInterface {
    let width = 1usize << rng.range(2, 5); // 4..16 bytes
    MemInterface {
        name: "@rand".into(),
        width,
        max_beats: 1 << rng.range(0, 4),
        in_flight: rng.range(1, 4),
        read_lead: rng.range(1, 8) as u64,
        write_cost: rng.range(1, 4) as u64,
        line: 64,
        level: aquas::interface::cache::HierarchyLevel::L2,
    }
}

#[test]
fn prop_interface_set_selection_total() {
    // Selection must assign every op for arbitrary small op mixes.
    use aquas::interface::cache::CacheHint;
    use aquas::ir::builder::FuncBuilder;
    use aquas::runtime::DType;
    use aquas::synthesis::{memprobe, selection, SynthOptions};
    let mut rng = Rng::new(0x5E1);
    for case in 0..40 {
        let mut b = FuncBuilder::new("sel");
        let n_bufs = rng.range(1, 4);
        let mut smems = Vec::new();
        let mut pairs = Vec::new();
        for i in 0..n_bufs {
            let len = rng.range(4, 64);
            let hint = *rng.choose(&[CacheHint::Warm, CacheHint::Cold, CacheHint::Unknown]);
            let g = b.global(&format!("g{i}"), DType::F32, len, hint);
            let s = b.scratchpad(&format!("s{i}"), DType::F32, len, 1);
            smems.push(s);
            pairs.push((g, s, len));
        }
        let zero = b.const_i(0);
        for &(g, s, len) in &pairs {
            b.transfer(s, zero, g, zero, len * 4);
        }
        // keep scratchpads alive (written) so elision isn't a factor
        b.for_range(0, 4, 1, |b, iv| {
            for &s in &smems {
                let v = b.read_smem(s, iv);
                let w = b.add(v, v);
                b.write_smem(s, iv, w);
            }
        });
        let f = b.finish(&[]);
        let itfcs = InterfaceSet::rocket_default();
        let probe = memprobe::extract(&f).unwrap();
        let assigns = selection::select(&probe, &itfcs, &SynthOptions::default())
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(assigns.len(), probe.ops.len(), "case {case}");
        for a in &assigns {
            let total: usize = a.segments.iter().sum();
            assert_eq!(total, probe.ops[a.op].bytes, "case {case} op {}", a.op);
        }
    }
}
