//! Property tests for the design-space explorer (`aquas explore`).
//!
//! The contract under test, per ISSUE 10 / ROADMAP item 5:
//!
//! 1. **Mutual non-domination** — no frontier member weakly dominates
//!    another;
//! 2. **Bitwise determinism** — replaying a run with the same
//!    seed/space/budget reproduces every evaluation and the frontier
//!    down to the IEEE-754 bits of the area objective, exhaustive and
//!    sampled alike;
//! 3. **Area-budget monotonicity** — growing the area budget never
//!    worsens the best-cycles point;
//! 4. **Baseline coverage** — the frontier weakly dominates every
//!    hand-picked §6.1 configuration;
//! 5. **Cost-oracle pinning** (differential) — the explorer's memory
//!    cycles equal `scheduling::simulate_schedule`'s dmasim replay
//!    exactly, its compute/overhead terms equal the `IsaxEngine` model,
//!    and its area equals the `AreaModel` pricing of the hwgen census
//!    on the same synthesized result: no second timing or area model.

use aquas::area::AreaModel;
use aquas::compiler::CompileBudget;
use aquas::cores::IsaxEngine;
use aquas::dse::{
    dominates, evaluate_point, specialize_isax, weakly_dominates, workloads, DesignPoint,
    DesignSpace, Explorer, PointCost,
};
use aquas::synthesis::{hwgen, scheduling, synthesize};

/// A 16-point sub-space tier-1 can afford to run several times.
fn small_explorer() -> Explorer {
    let mut ex = Explorer::demo();
    ex.space = DesignSpace::parse("width=8|16,burst=1|8,inflight=1|2,banks=1|2,unroll=1")
        .expect("small space parses");
    ex
}

fn costs_bitwise_equal(a: &PointCost, b: &PointCost) -> bool {
    a.point == b.point
        && a.cycles == b.cycles
        && a.area_mm2.to_bits() == b.area_mm2.to_bits()
        && a.freq_mhz.to_bits() == b.freq_mhz.to_bits()
        && a.per_workload.len() == b.per_workload.len()
        && a.per_workload.iter().zip(&b.per_workload).all(|(x, y)| {
            x.name == y.name
                && x.sim_mem_cycles == y.sim_mem_cycles
                && x.conflict_cycles == y.conflict_cycles
                && x.compute_cycles == y.compute_cycles
                && x.overhead == y.overhead
                && x.isax_area_mm2.to_bits() == y.isax_area_mm2.to_bits()
        })
}

#[test]
fn frontier_is_mutually_nondominated() {
    let r = small_explorer().run().expect("explore");
    assert!(!r.frontier.is_empty(), "frontier must not be empty");
    assert!(r.frontier_mutually_nondominated());
    for a in &r.frontier {
        for b in &r.frontier {
            if a.point != b.point {
                assert!(
                    !weakly_dominates(a, b),
                    "{} weakly dominates {}",
                    a.point.key(),
                    b.point.key()
                );
            }
        }
    }
    // Every evaluated point is weakly dominated by some frontier point
    // (the frontier is a complete lower envelope, not just non-dominated).
    for c in &r.evaluated {
        assert!(
            r.frontier.iter().any(|f| weakly_dominates(f, c)),
            "{} escaped the envelope",
            c.point.key()
        );
    }
}

#[test]
fn same_seed_replay_is_bitwise_identical() {
    let ex = small_explorer();
    let a = ex.run().expect("run a");
    let b = ex.run().expect("run b");
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(a.evaluated.len(), b.evaluated.len());
    for (x, y) in a.evaluated.iter().zip(&b.evaluated) {
        assert!(costs_bitwise_equal(x, y), "evaluation diverged at {}", x.point.key());
    }
    assert_eq!(a.infeasible, b.infeasible);
}

#[test]
fn sampled_search_is_seed_deterministic() {
    let mut ex = small_explorer();
    ex.sample_limit = 6; // 16-cell space -> genuinely sampled
    let a = ex.run().expect("sampled a");
    let b = ex.run().expect("sampled b");
    assert!(a.sampled, "space must exceed the sample limit");
    assert_eq!(a.fingerprint(), b.fingerprint());
    assert_eq!(
        a.evaluated.iter().map(|c| c.point).collect::<Vec<_>>(),
        b.evaluated.iter().map(|c| c.point).collect::<Vec<_>>(),
        "seeded sampling must draw the same candidates"
    );
    // A different seed is also deterministic, but may legitimately draw
    // a different candidate set; both runs must still self-agree.
    ex.seed ^= 0xDEAD_BEEF;
    let c = ex.run().expect("other seed a");
    let d = ex.run().expect("other seed b");
    assert_eq!(c.fingerprint(), d.fingerprint());
}

#[test]
fn growing_area_budget_never_worsens_best_cycles() {
    let r = small_explorer().run().expect("explore");
    let mut areas: Vec<f64> = r.evaluated.iter().map(|c| c.area_mm2).collect();
    areas.sort_by(f64::total_cmp);
    let mut prev: Option<u64> = None;
    for cap in areas {
        let best = r.best_cycles_within(Some(cap));
        if let (Some(p), Some(b)) = (prev, best) {
            assert!(b <= p, "best cycles worsened from {p} to {b} at cap {cap}");
        }
        if best.is_some() {
            prev = best;
        }
    }
    assert_eq!(r.best_cycles_within(None), prev, "unbounded budget = largest cap");

    // The same law through the Explorer's own area_budget_mm2 filter:
    // a capped frontier's best point can never beat the uncapped one.
    let mut capped = small_explorer();
    let mid = r.evaluated[r.evaluated.len() / 2].area_mm2;
    capped.area_budget_mm2 = Some(mid);
    let rc = capped.run().expect("capped explore");
    let capped_best = rc.frontier.iter().map(|c| c.cycles).min();
    let open_best = r.frontier.iter().map(|c| c.cycles).min();
    if let (Some(cb), Some(ob)) = (capped_best, open_best) {
        assert!(ob <= cb, "uncapped best {ob} must not be worse than capped best {cb}");
    }
}

#[test]
fn frontier_dominates_every_handpicked_config() {
    let r = Explorer::demo().run().expect("demo explore");
    assert_eq!(r.baselines.len(), 2, "both §6.1 configs must evaluate");
    assert!(r.frontier_covers_baselines());
    for b in &r.baselines {
        let covered = r
            .frontier
            .iter()
            .any(|f| dominates(f, b) || (weakly_dominates(f, b) && f.point == b.point));
        assert!(
            covered || r.frontier.iter().any(|f| weakly_dominates(f, b)),
            "baseline {} not covered",
            b.point.key()
        );
    }
}

#[test]
fn cost_oracle_matches_simulate_schedule_and_hwgen_census() {
    let ws = workloads().expect("workloads");
    let budget = CompileBudget::default();
    let model = AreaModel::default();
    for point in [
        DesignPoint::handpicked_default(),
        DesignPoint { width: 16, burst: 8, in_flight: 2, banks: 4, unroll: 2 },
        DesignPoint { width: 4, burst: 1, in_flight: 1, banks: 1, unroll: 1 },
    ] {
        let cost = evaluate_point(&ws, &point, &budget).expect("evaluate");
        let itfcs = point.interfaces();
        let mut descs = Vec::new();
        assert_eq!(cost.per_workload.len(), ws.len());
        for (w, wc) in ws.iter().zip(&cost.per_workload) {
            assert_eq!(w.name, wc.name);
            // Re-run the pipeline by hand and pin every term.
            let spec = specialize_isax(&w.isax, &point, budget.pass_rounds).expect("specialize");
            let synth = synthesize(&spec, &itfcs, &w.synth_opts).expect("synthesize");
            let sim = scheduling::simulate_schedule(&synth.schedule, &itfcs).expect("replay");
            assert_eq!(
                wc.sim_mem_cycles, sim.makespan,
                "{}: memory cycles must equal the dmasim replay exactly",
                w.name
            );
            assert_eq!(wc.conflict_cycles, sim.conflict_cycles, "{}: conflicts", w.name);
            let desc = hwgen::generate(&synth, &itfcs);
            let engine = IsaxEngine::from_synthesis(&synth, &desc, &itfcs);
            assert_eq!(wc.compute_cycles, engine.compute_cycles, "{}: compute model", w.name);
            assert_eq!(wc.overhead, engine.overhead, "{}: overhead model", w.name);
            assert_eq!(
                wc.isax_area_mm2.to_bits(),
                model.isax_area(&desc).to_bits(),
                "{}: area must equal the hwgen census pricing bitwise",
                w.name
            );
            descs.push(desc);
        }
        let refs: Vec<&hwgen::PipelineDesc> = descs.iter().collect();
        let soc = model.rocket_with_isaxes(&refs);
        assert_eq!(cost.area_mm2.to_bits(), soc.area_mm2.to_bits(), "SoC area pinned");
        assert_eq!(cost.freq_mhz.to_bits(), soc.freq_mhz.to_bits(), "SoC clock pinned");
        assert_eq!(
            cost.cycles,
            cost.per_workload.iter().map(|w| w.cycles()).sum::<u64>(),
            "joint objective is the per-family sum"
        );
    }
}

#[test]
fn axes_are_live_in_the_oracle() {
    let ws = workloads().expect("workloads");
    let budget = CompileBudget::default();
    let base = DesignPoint::handpicked_default();
    let narrow = DesignPoint { width: 4, burst: 1, in_flight: 1, ..base };
    let banked = DesignPoint { banks: 4, ..base };
    let cb = evaluate_point(&ws, &base, &budget).expect("base");
    let cn = evaluate_point(&ws, &narrow, &budget).expect("narrow");
    let ck = evaluate_point(&ws, &banked, &budget).expect("banked");
    assert!(
        cn.cycles > cb.cycles,
        "a narrow no-burst bus must cost cycles: {} vs {}",
        cn.cycles,
        cb.cycles
    );
    assert!(
        ck.area_mm2 > cb.area_mm2,
        "extra banks must cost area: {} vs {}",
        ck.area_mm2,
        cb.area_mm2
    );
}

#[test]
fn infeasible_unroll_is_recorded_not_fatal() {
    let mut ex = Explorer::demo();
    // unroll=16 divides the gf2mm/pqc/pcp trip counts but not the
    // attention tile's 8 -> the point is infeasible as a whole and must
    // be skipped diagnostically while the baselines still evaluate.
    ex.space = DesignSpace::parse("width=8,burst=8,inflight=2,banks=2,unroll=16")
        .expect("spec parses");
    let r = ex.run().expect("run survives infeasible points");
    assert_eq!(r.infeasible.len(), 1, "the unroll=16 point is infeasible");
    assert!(r.infeasible[0].1.contains("attention"), "reason names the family");
    assert_eq!(r.baselines.len(), 2);
    assert!(!r.frontier.is_empty());
}
