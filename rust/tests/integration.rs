//! Cross-module integration: the full Aquas flow (describe → synthesize →
//! compile → simulate) on every case-study kernel, plus HW/SW semantic
//! equivalence between each ISAX's functional description and its
//! synthesized temporal form.

use aquas::compiler::{compile, CompileOptions};
use aquas::cores::rocket::{CoreConfig, RocketModel};
use aquas::cores::IsaxEngine;
use aquas::ir::interp::{run as interp, Memory};
use aquas::ir::ops::OpKind;
use aquas::synthesis::{hwgen, naive, synthesize};
use aquas::workloads::{graphics_kernels, table2_kernels, Kernel};

fn all_kernels() -> Vec<Kernel> {
    let mut ks = table2_kernels();
    ks.extend(graphics_kernels());
    ks
}

#[test]
fn full_flow_on_every_kernel() {
    for k in all_kernels() {
        // Synthesis must produce a verifiable temporal form.
        let synth = synthesize(&k.isax.func, &k.itfcs, &k.synth_opts)
            .unwrap_or_else(|e| panic!("{}: synth {e}", k.name));
        aquas::ir::verifier::verify(&synth.temporal)
            .unwrap_or_else(|e| panic!("{}: temporal verify {e}", k.name));

        // Hardware generation + engine timing.
        let desc = hwgen::generate(&synth, &k.itfcs);
        let engine = IsaxEngine::from_synthesis(&synth, &desc, &k.itfcs);
        assert!(engine.cycles_per_invocation() > 0, "{}", k.name);

        // Compilation must offload the canonical software.
        let lowered = compile(&k.software, &[k.isax.clone()], &CompileOptions::default())
            .unwrap_or_else(|e| panic!("{}: compile {e}", k.name));
        assert_eq!(
            lowered.func.count_ops(|o| matches!(o, OpKind::Intrinsic(_))),
            1,
            "{}",
            k.name
        );

        // The accelerated program must beat the base core.
        let base = RocketModel::new(CoreConfig::default());
        let mut m1 = Memory::for_func(&k.software);
        (k.init)(&k.software, &mut m1);
        let rb = base.simulate(&k.software, &[], &mut m1).unwrap();
        let acc = RocketModel::new(CoreConfig::default())
            .with_isax(&k.isax.name, engine.cycles_per_invocation());
        let mut m2 = Memory::for_func(&lowered.func);
        (k.init)(&lowered.func, &mut m2);
        let ra = acc.simulate(&lowered.func, &[], &mut m2).unwrap();
        assert!(ra.cycles < rb.cycles, "{}: {} !< {}", k.name, ra.cycles, rb.cycles);
    }
}

#[test]
fn synthesis_preserves_isax_semantics_everywhere() {
    // functional description == synthesized temporal form, numerically,
    // for both the Aquas and the naive flow.
    for k in all_kernels() {
        let smart = synthesize(&k.isax.func, &k.itfcs, &k.synth_opts).unwrap();
        let nai = naive::synthesize_naive(&k.isax.func, &k.itfcs).unwrap();
        for (flow, func) in [("aquas", &smart.temporal), ("naive", &nai.temporal)] {
            let mut m1 = Memory::for_func(&k.isax.func);
            (k.init)(&k.isax.func, &mut m1);
            interp(&k.isax.func, &[], &mut m1).unwrap();
            let mut m2 = Memory::for_func(func);
            (k.init)(func, &mut m2);
            interp(func, &[], &mut m2)
                .unwrap_or_else(|e| panic!("{} {flow}: {e}", k.name));
            for out in &k.outputs {
                let want = m1.read_f32(Kernel::buf(&k.isax.func, out));
                let got = m2.read_f32(Kernel::buf(func, out));
                for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-4 * a.abs().max(1.0),
                        "{} {flow} {out}[{i}]: {a} vs {b}",
                        k.name
                    );
                }
            }
        }
    }
}

#[test]
fn every_variant_still_matches_its_isax() {
    for k in all_kernels() {
        for (desc, variant) in &k.variants {
            let r = compile(variant, &[k.isax.clone()], &CompileOptions::default())
                .unwrap_or_else(|e| panic!("{} {desc}: {e}", k.name));
            assert!(
                r.stats.matched.contains(&k.isax.name),
                "{} variant `{desc}` failed: {:?}",
                k.name,
                r.stats
            );
        }
    }
}

#[test]
fn compiled_program_is_semantically_unchanged_outside_offload() {
    // Lowering replaces loops with intrinsics; stripping the intrinsic and
    // re-running the *original* must agree with running the original
    // directly (i.e. lowering never mutates surrounding code).
    for k in all_kernels().into_iter().take(4) {
        let lowered =
            compile(&k.software, &[k.isax.clone()], &CompileOptions::default()).unwrap().func;
        // every non-intrinsic top-level op of `lowered` appears in the
        // original entry too (same arity of anchors +/- the loop).
        let orig_anchors = k.software.entry.ops.len();
        let new_anchors = lowered.entry.ops.len();
        assert_eq!(orig_anchors, new_anchors, "{}", k.name);
    }
}

#[test]
fn area_reports_consistent_across_flows() {
    use aquas::area::AreaModel;
    let model = AreaModel::default();
    for k in all_kernels() {
        let smart = synthesize(&k.isax.func, &k.itfcs, &k.synth_opts).unwrap();
        let desc = hwgen::generate(&smart, &k.itfcs);
        let rep = model.rocket_with_isaxes(&[&desc]);
        assert!(rep.area_mm2 > aquas::area::ROCKET_AREA_MM2, "{}", k.name);
        assert!(rep.area_overhead_pct() < 30.0, "{}: {:.1}%", k.name, rep.area_overhead_pct());
    }
}
