//! `cargo bench --bench dma` — burst-DMA memory-subsystem benchmark.
//!
//! Sweeps Figure-2-style interface configurations (width × burst ×
//! in-flight) over the gf2mm / attention / KV-gather transaction traces,
//! pricing each through the event-driven simulator
//! (`interface::dmasim`) and the closed-form §4.1/§4.3 models (see
//! `bench_harness::dma`). Writes the raw metrics to `--out` (default
//! `BENCH_dma.json`) and — with `--check` — enforces the CI gates:
//!
//! - single-stream replays equal `sequence_latency` exactly (the
//!   uncontended-regime agreement the whole timing stack rests on);
//! - the §4.3 `T_k` estimate is exact for stores and within its
//!   documented 50% bound for loads *against the simulator*;
//! - bank conflicts appear on a single-banked scratchpad shared by two
//!   interfaces and vanish with two banks;
//! - coalescing contiguous words into bursts strictly wins.
//!
//! `-- --test` is the CI smoke mode (smaller sweep).

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--test");
    let out_path = flag_value(&args, "--out").unwrap_or_else(|| "BENCH_dma.json".to_string());
    let check = args.iter().any(|a| a == "--check");

    let report = aquas::bench_harness::dma::report(quick);
    println!("{}", report.render());

    std::fs::write(&out_path, report.metrics_json())
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("report written to {out_path}");

    if check {
        let mut failed = false;
        for (metric, why) in [
            (
                "uncontended_sim_matches_recurrence",
                "event simulator diverged from the exact §4.1 recurrence on a \
                 single uncontended stream",
            ),
            ("tk_store_exact", "§4.3 T_k store form no longer reproduces the simulator"),
            ("tk_load_within_bound", "§4.3 T_k load form left its documented 50% bound"),
            (
                "bank_conflicts_resolve",
                "bank-conflict model broke: single-bank sharing must conflict, \
                 dual-bank must not",
            ),
            ("coalescing_wins", "burst coalescing stopped beating word-by-word issue"),
        ] {
            if report.metrics.get(metric) != Some(&1.0) {
                eprintln!("GATE FAILED: {metric} != 1 ({why}); see {out_path}");
                failed = true;
            }
        }
        if failed {
            std::process::exit(1);
        }
        println!(
            "checks ok: sim ≡ recurrence uncontended; T_k store exact / load ≤50%; \
             bank conflicts appear at 1 bank ({} cyc) and resolve at 2; coalescing wins",
            report.metrics["contended_conflict_cycles"]
        );
    }
}
