//! `cargo bench --bench interp` — IR interpreter engine benchmark.
//!
//! Three sections:
//! 1. the library report (`bench_harness::interp::report`): every AOT
//!    kernel at manifest shapes executed through the tree-walking oracle
//!    and the compiled register-bytecode VM, recording wall time,
//!    compile cost and per-kernel `speedup_vs_legacy` (the tree-walker
//!    *is* the legacy engine and stays in-tree as the oracle, so no
//!    embedded copy is needed);
//! 2. a **seeded random-program fuzz sweep**: `random_program` generates
//!    nested-loop/if/copy/irf programs and `check_equivalent` demands
//!    bit-identical outputs, memory images, irf, `ExecStats` — or
//!    identical failures — from both engines; every seed additionally
//!    goes through the full `ir::passes` pipeline and
//!    `check_opt_equivalent` demands the optimized program stay
//!    observationally identical (outputs/memory/irf/errors) on both
//!    engines;
//! 3. the JSON report (`--out <path>`, default `BENCH_interp.json`) and
//!    the CI gate (`--check`): fails on ANY divergence (kernels, fuzz
//!    seeds, optimized variants, fuel-metering sweeps, or the hostile-
//!    input no-panic smoke — every metric ending `_agree` must be 1),
//!    a geo-mean speedup below 5x, or a mid-end dynamic-op reduction
//!    below 20% on `attention` / `gf2mm`.
//!
//! `-- --test` is the CI smoke mode (fewer reps / seeds).

use aquas::bench_harness::interp::{
    check_equivalent, check_fuel_equivalent, check_opt_equivalent, random_program,
};
use aquas::ir::passes::{optimize, OptLevel};

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--test");
    let out_path =
        flag_value(&args, "--out").unwrap_or_else(|| "BENCH_interp.json".to_string());
    let check = args.iter().any(|a| a == "--check");

    // 1. Kernel replay through both engines.
    let mut report = aquas::bench_harness::interp::report(quick);

    // 2. Fuzz sweep: seeded random programs, exact equivalence demanded —
    //    both between the two engines and across the pass pipeline.
    let n_seeds: u64 = if quick { 32 } else { 128 };
    let mut failures: Vec<String> = Vec::new();
    let mut opt_failures: Vec<String> = Vec::new();
    let mut fuel_failures: Vec<String> = Vec::new();
    for seed in 0..n_seeds {
        let f = random_program(seed);
        if let Err(e) = check_equivalent(&f, seed) {
            failures.push(e);
        }
        if let Err(e) = check_fuel_equivalent(&f, seed) {
            fuel_failures.push(e);
        }
        match optimize(&f, OptLevel::O2) {
            Ok((opt, _)) => {
                if let Err(e) = check_opt_equivalent(&f, &opt, seed) {
                    opt_failures.push(e);
                }
            }
            Err(e) => opt_failures.push(format!("seed {seed}: pipeline failed: {e}")),
        }
    }
    println!(
        "fuzz: {n_seeds} seeded random programs through both engines, {} divergence(s); \
         optimized variants, {} divergence(s); fuel sweeps, {} divergence(s)",
        failures.len(),
        opt_failures.len(),
        fuel_failures.len()
    );
    for e in &failures {
        eprintln!("FUZZ DIVERGENCE: {e}");
    }
    for e in &opt_failures {
        eprintln!("OPT FUZZ DIVERGENCE: {e}");
    }
    for e in &fuel_failures {
        eprintln!("FUEL FUZZ DIVERGENCE: {e}");
    }
    report.metric("fuzz_seeds", n_seeds as f64);
    report.metric("fuzz_agree", if failures.is_empty() { 1.0 } else { 0.0 });
    report.metric("opt_fuzz_seeds", n_seeds as f64);
    report.metric("opt_fuzz_agree", if opt_failures.is_empty() { 1.0 } else { 0.0 });
    report.metric("fuel_fuzz_agree", if fuel_failures.is_empty() { 1.0 } else { 0.0 });

    println!("\n{}", report.render());

    // 3. JSON report + gates.
    std::fs::write(&out_path, report.metrics_json())
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("report written to {out_path}");

    if check {
        let mut failed = false;
        // Gate 1: the differential — every kernel and every fuzz seed
        // must agree between the VM and the tree-walking oracle.
        for (metric, value) in &report.metrics {
            if metric.ends_with("_agree") && *value != 1.0 {
                eprintln!("GATE FAILED: {metric} != 1 (engines diverge); see {out_path}");
                failed = true;
            }
        }
        // Gate 2: the point of the rewrite — compile-once execution must
        // hold a geo-mean speedup of at least 5x over the tree-walker.
        let geomean = report.metrics["geomean_speedup_vs_legacy"];
        if geomean < 5.0 {
            eprintln!(
                "REGRESSION: geo-mean speedup {geomean:.2}x over the tree-walker is \
                 below the 5x acceptance bar"
            );
            failed = true;
        }
        // Gate 3: the mid-end must actually pay for itself — at least a
        // 20% dynamic-op reduction on the two index-math-heavy kernels.
        for kernel in ["attention", "gf2mm"] {
            let key = format!("{kernel}_dynop_reduction");
            let red = report.metrics[key.as_str()];
            if red < 0.20 {
                eprintln!(
                    "REGRESSION: {kernel} dynamic-op reduction {:.1}% is below the \
                     20% acceptance bar",
                    red * 100.0
                );
                failed = true;
            }
        }
        if failed {
            std::process::exit(1);
        }
        println!(
            "checks ok: VM ≡ tree-walker on all kernels + {n_seeds} fuzz seeds; \
             pipeline ≡ identity on all kernels + fuzz seeds; geo-mean speedup \
             {geomean:.2}x (gate: 5x); attention/gf2mm dynamic ops cut ≥20%"
        );
    }
}
