//! `cargo bench --bench hotpath` — micro-benchmarks of the three hot
//! paths the §Perf pass optimizes: e-graph saturation + matching, the
//! memoized transaction-scheduling search, and the serving-loop step
//! (PJRT decode round). Criterion replacement; see DESIGN.md.

use std::time::Instant;

fn time_ms<F: FnMut()>(n: usize, mut f: F) -> aquas::util::stats::Summary {
    // warm-up
    f();
    let samples: Vec<f64> = (0..n)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    aquas::util::stats::summarize(samples)
}

fn main() {
    // 1. Compiler matching (encode + saturate + match) on the heaviest
    //    kernel (mcov, 3-deep nest) and on a tiled variant.
    let ks = aquas::workloads::pcp::kernels();
    let mcov = ks.iter().find(|k| k.name == "mcov.vs").unwrap();
    let s = time_ms(20, || {
        let r = aquas::compiler::compile(
            &mcov.software,
            &[mcov.isax.clone()],
            &Default::default(),
        )
        .unwrap();
        assert!(!r.stats.matched.is_empty());
    });
    println!("compile/match mcov canonical: mean {:.3} ms p95 {:.3} ms", s.mean, s.p95);

    let (desc, variant) = &mcov.variants[0];
    let s = time_ms(20, || {
        let r = aquas::compiler::compile(variant, &[mcov.isax.clone()], &Default::default())
            .unwrap();
        assert!(!r.stats.matched.is_empty());
    });
    println!("compile/match mcov {desc}: mean {:.3} ms p95 {:.3} ms", s.mean, s.p95);

    // 2. Synthesis (elision + selection + memoized scheduling) on fir7.
    let f = aquas::bench_harness::fir7::fir7();
    let itfcs = aquas::interface::model::InterfaceSet::rocket_default();
    let s = time_ms(50, || {
        let r = aquas::synthesis::synthesize(&f, &itfcs, &Default::default()).unwrap();
        assert!(r.schedule.mem_latency() > 0);
    });
    println!("synthesize fir7:              mean {:.3} ms p95 {:.3} ms", s.mean, s.p95);

    // 3. Cycle simulation of the biggest workload (PQC e2e).
    let e2e = aquas::workloads::pqc::end_to_end_software();
    let model =
        aquas::cores::rocket::RocketModel::new(aquas::cores::rocket::CoreConfig::default());
    let s = time_ms(10, || {
        let mut mem = aquas::ir::interp::Memory::for_func(&e2e);
        aquas::workloads::pqc::init_end_to_end(&e2e, &mut mem);
        let r = model.simulate(&e2e, &[], &mut mem).unwrap();
        assert!(r.cycles > 0);
    });
    println!("simulate pqc e2e (rocket):    mean {:.3} ms p95 {:.3} ms", s.mean, s.p95);

    // 4. Serving loop: one decode round through PJRT (needs artifacts).
    match aquas::runtime::Runtime::load("artifacts") {
        Ok(rt) => {
            rt.compile_entry("llm_prefill").unwrap();
            rt.compile_entry("llm_decode").unwrap();
            let mut coord = aquas::coordinator::Coordinator::new(&rt, Default::default());
            coord.submit(vec![1, 2, 3, 4, 5, 6, 7, 8], 50).unwrap();
            coord.step().unwrap(); // prefill
            let s = time_ms(30, || {
                // one decode step per iteration (bounded by max_new_tokens = 50
                // which covers warm-up + the 30 timed steps)
                let _ = coord.step().unwrap();
            });
            println!("serving decode step (PJRT):   mean {:.3} ms p95 {:.3} ms", s.mean, s.p95);
        }
        Err(e) => println!("serving decode step: skipped ({e})"),
    }
}
