//! `cargo bench --bench hotpath` — micro-benchmarks of the hot paths:
//! e-graph saturation + matching, the memoized transaction-scheduling
//! search, cycle simulation, and the serving-loop decode step through
//! the simulated runtime. Criterion replacement; see DESIGN.md.

use std::time::Instant;

fn time_ms<F: FnMut()>(n: usize, mut f: F) -> aquas::util::stats::Summary {
    // warm-up
    f();
    let samples: Vec<f64> = (0..n)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    aquas::util::stats::summarize(samples)
}

fn main() {
    // `cargo bench --bench hotpath -- --test` (the CI smoke) runs one
    // timed iteration per section instead of the full sample counts.
    let smoke = std::env::args().any(|a| a == "--test");
    let n = |full: usize| if smoke { 1 } else { full };

    // 1. Compiler matching (encode + saturate + match) on the heaviest
    //    kernel (mcov, 3-deep nest) and on a tiled variant.
    let ks = aquas::workloads::pcp::kernels();
    let mcov = ks.iter().find(|k| k.name == "mcov.vs").unwrap();
    let s = time_ms(n(20), || {
        let r = aquas::compiler::compile(
            &mcov.software,
            &[mcov.isax.clone()],
            &Default::default(),
        )
        .unwrap();
        assert!(!r.stats.matched.is_empty());
    });
    println!("compile/match mcov canonical: mean {:.3} ms p95 {:.3} ms", s.mean, s.p95);

    let (desc, variant) = &mcov.variants[0];
    let s = time_ms(n(20), || {
        let r = aquas::compiler::compile(variant, &[mcov.isax.clone()], &Default::default())
            .unwrap();
        assert!(!r.stats.matched.is_empty());
    });
    println!("compile/match mcov {desc}: mean {:.3} ms p95 {:.3} ms", s.mean, s.p95);

    // 2. Synthesis (elision + selection + memoized scheduling) on fir7.
    let f = aquas::bench_harness::fir7::fir7();
    let itfcs = aquas::interface::model::InterfaceSet::rocket_default();
    let s = time_ms(n(50), || {
        let r = aquas::synthesis::synthesize(&f, &itfcs, &Default::default()).unwrap();
        assert!(r.schedule.mem_latency() > 0);
    });
    println!("synthesize fir7:              mean {:.3} ms p95 {:.3} ms", s.mean, s.p95);

    // 3. Cycle simulation of the biggest workload (PQC e2e).
    let e2e = aquas::workloads::pqc::end_to_end_software();
    let model =
        aquas::cores::rocket::RocketModel::new(aquas::cores::rocket::CoreConfig::default());
    let s = time_ms(n(10), || {
        let mut mem = aquas::ir::interp::Memory::for_func(&e2e);
        aquas::workloads::pqc::init_end_to_end(&e2e, &mut mem);
        let r = model.simulate(&e2e, &[], &mut mem).unwrap();
        assert!(r.cycles > 0);
    });
    println!("simulate pqc e2e (rocket):    mean {:.3} ms p95 {:.3} ms", s.mean, s.p95);

    // 4. Serving loop: one decode step through the runtime (uses the
    //    built-in simulated manifest when no artifacts exist).
    match aquas::runtime::Runtime::load("artifacts") {
        Ok(rt) => {
            rt.compile_entry("llm_prefill").unwrap();
            rt.compile_entry("llm_decode").unwrap();
            let mut coord = aquas::coordinator::Coordinator::new(&rt, Default::default());
            coord.submit(vec![1, 2, 3, 4, 5, 6, 7, 8], 50).unwrap();
            coord.step().unwrap(); // prefill
            let s = time_ms(n(30), || {
                // one decode step per iteration (bounded by max_new_tokens = 50
                // which covers warm-up + the 30 timed steps)
                let _ = coord.step().unwrap();
            });
            println!("serving decode step (sim):    mean {:.3} ms p95 {:.3} ms", s.mean, s.p95);
        }
        Err(e) => println!("serving decode step: skipped ({e})"),
    }
}
