//! `cargo bench --bench egraph` — e-graph engine benchmarks.
//!
//! Three sections:
//! 1. the library report (`bench_harness::egraph::report`): saturation
//!    time, e-nodes/sec and match-round latency per workload on the
//!    current engine;
//! 2. an **old-vs-new comparison**: the `legacy` module below is a copy
//!    of the pre-PR engine (full-memo-rehash `rebuild`, per-class scan
//!    with string-keyed `HashMap` bindings). Both engines replay the same
//!    encoded term graphs and saturate with the same rule set; the
//!    speedup is recorded in the report;
//! 3. the JSON report (`--out <path>`, default `BENCH_egraph.json`) and
//!    the CI regression gate (`--check <baseline.json>` fails the run if
//!    gf2mm saturation regresses >2x against the checked-in baseline).
//!
//! `-- --test` is the CI smoke mode: one sample per section.

use std::time::Instant;

use aquas::bench_harness::egraph::{
    attention_term_graph, bench_runner, gf2mm_term_graph, replay, TermGraph,
};
use aquas::compiler::rules::internal_rules;
use aquas::util::stats::summarize;

// ---------------------------------------------------------------------------
// The pre-PR engine, kept verbatim for comparison. `rebuild` rehashes the
// whole memo per fixpoint iteration, `nodes`/`nodes_with_sym` clone node
// vectors, and matching scans every class with string-keyed HashMap
// bindings cloned per branch. The pattern AST is shared with the library.
// ---------------------------------------------------------------------------
#[allow(dead_code)]
mod legacy {
    use aquas::egraph::Pattern;
    use std::collections::HashMap;

    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct SymId(pub u32);

    #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
    pub struct ClassId(pub u32);

    #[derive(Debug, Clone, PartialEq, Eq, Hash)]
    pub struct ENode {
        pub sym: SymId,
        pub children: Vec<ClassId>,
    }

    impl ENode {
        fn canonicalize(&self, uf: &mut UnionFind) -> ENode {
            ENode {
                sym: self.sym,
                children: self.children.iter().map(|&c| uf.find(c)).collect(),
            }
        }
    }

    #[derive(Debug, Default, Clone)]
    struct UnionFind {
        parent: Vec<u32>,
    }

    impl UnionFind {
        fn make(&mut self) -> ClassId {
            let id = self.parent.len() as u32;
            self.parent.push(id);
            ClassId(id)
        }

        fn find(&mut self, c: ClassId) -> ClassId {
            let mut root = c.0;
            while self.parent[root as usize] != root {
                root = self.parent[root as usize];
            }
            let mut cur = c.0;
            while self.parent[cur as usize] != root {
                let next = self.parent[cur as usize];
                self.parent[cur as usize] = root;
                cur = next;
            }
            ClassId(root)
        }

        fn union(&mut self, a: ClassId, b: ClassId) -> ClassId {
            let ra = self.find(a);
            let rb = self.find(b);
            if ra != rb {
                let (keep, drop) = if ra.0 < rb.0 { (ra, rb) } else { (rb, ra) };
                self.parent[drop.0 as usize] = keep.0;
                keep
            } else {
                ra
            }
        }
    }

    #[derive(Debug, Default, Clone)]
    pub struct EGraph {
        syms: Vec<String>,
        sym_ids: HashMap<String, SymId>,
        uf: UnionFind,
        memo: HashMap<ENode, ClassId>,
        classes: HashMap<ClassId, Vec<ENode>>,
        dirty: Vec<ClassId>,
    }

    impl EGraph {
        pub fn new() -> Self {
            Self::default()
        }

        pub fn sym(&mut self, name: &str) -> SymId {
            if let Some(&id) = self.sym_ids.get(name) {
                return id;
            }
            let id = SymId(self.syms.len() as u32);
            self.syms.push(name.to_string());
            self.sym_ids.insert(name.to_string(), id);
            id
        }

        pub fn find_sym(&self, name: &str) -> Option<SymId> {
            self.sym_ids.get(name).copied()
        }

        pub fn sym_name(&self, s: SymId) -> &str {
            &self.syms[s.0 as usize]
        }

        pub fn find(&mut self, c: ClassId) -> ClassId {
            self.uf.find(c)
        }

        pub fn add(&mut self, node: ENode) -> ClassId {
            let node = node.canonicalize(&mut self.uf);
            if let Some(&c) = self.memo.get(&node) {
                return self.uf.find(c);
            }
            let id = self.uf.make();
            self.memo.insert(node.clone(), id);
            self.classes.entry(id).or_default().push(node);
            id
        }

        pub fn add_named(&mut self, name: &str, children: Vec<ClassId>) -> ClassId {
            let sym = self.sym(name);
            self.add(ENode { sym, children })
        }

        pub fn union(&mut self, a: ClassId, b: ClassId) -> ClassId {
            let ra = self.uf.find(a);
            let rb = self.uf.find(b);
            if ra == rb {
                return ra;
            }
            let keep = self.uf.union(ra, rb);
            let drop = if keep == ra { rb } else { ra };
            let moved = self.classes.remove(&drop).unwrap_or_default();
            self.classes.entry(keep).or_default().extend(moved);
            self.dirty.push(keep);
            keep
        }

        pub fn rebuild(&mut self) {
            while !self.dirty.is_empty() {
                self.dirty.clear();
                let old_memo = std::mem::take(&mut self.memo);
                let mut new_memo: HashMap<ENode, ClassId> =
                    HashMap::with_capacity(old_memo.len());
                let mut unions: Vec<(ClassId, ClassId)> = Vec::new();
                for (node, cls) in old_memo {
                    let canon = node.canonicalize(&mut self.uf);
                    let ccls = self.uf.find(cls);
                    match new_memo.get(&canon) {
                        Some(&existing) if existing != ccls => unions.push((existing, ccls)),
                        Some(_) => {}
                        None => {
                            new_memo.insert(canon, ccls);
                        }
                    }
                }
                self.memo = new_memo;
                for (a, b) in unions {
                    self.union(a, b);
                }
                let mut new_classes: HashMap<ClassId, Vec<ENode>> = HashMap::new();
                let mut seen: std::collections::HashSet<(ClassId, ENode)> =
                    std::collections::HashSet::new();
                let old = std::mem::take(&mut self.classes);
                for (cls, nodes) in old {
                    let ccls = self.uf.find(cls);
                    for n in nodes {
                        let canon = n.canonicalize(&mut self.uf);
                        if seen.insert((ccls, canon.clone())) {
                            new_classes.entry(ccls).or_default().push(canon);
                        }
                    }
                }
                self.classes = new_classes;
            }
        }

        pub fn nodes(&mut self, c: ClassId) -> Vec<ENode> {
            let c = self.uf.find(c);
            self.classes.get(&c).cloned().unwrap_or_default()
        }

        pub fn nodes_with_sym(&mut self, c: ClassId, sym: SymId, arity: usize) -> Vec<ENode> {
            let c = self.uf.find(c);
            match self.classes.get(&c) {
                Some(ns) => ns
                    .iter()
                    .filter(|n| n.sym == sym && n.children.len() == arity)
                    .cloned()
                    .collect(),
                None => Vec::new(),
            }
        }

        pub fn class_ids(&mut self) -> Vec<ClassId> {
            let ids: Vec<ClassId> = self.classes.keys().copied().collect();
            ids.into_iter().map(|c| self.uf.find(c)).collect()
        }

        pub fn node_count(&self) -> usize {
            self.classes.values().map(|v| v.len()).sum()
        }
    }

    pub type Bindings = HashMap<String, ClassId>;

    pub enum Action {
        Template(Pattern),
        Dynamic(Box<dyn Fn(&mut EGraph, &Bindings) -> Option<ClassId>>),
    }

    pub struct Rewrite {
        pub name: String,
        pub lhs: Pattern,
        pub action: Action,
    }

    impl Rewrite {
        pub fn simple(name: &str, lhs: &str, rhs: &str) -> Self {
            Self {
                name: name.into(),
                lhs: Pattern::parse(lhs),
                action: Action::Template(Pattern::parse(rhs)),
            }
        }

        pub fn dynamic<F>(name: &str, lhs: &str, f: F) -> Self
        where
            F: Fn(&mut EGraph, &Bindings) -> Option<ClassId> + 'static,
        {
            Self {
                name: name.into(),
                lhs: Pattern::parse(lhs),
                action: Action::Dynamic(Box::new(f)),
            }
        }
    }

    pub fn match_pattern(
        g: &mut EGraph,
        pattern: &Pattern,
        c: ClassId,
        binds: &Bindings,
        sink: &mut Vec<Bindings>,
    ) {
        match pattern {
            Pattern::Var(v) => {
                let c = g.find(c);
                match binds.get(v) {
                    Some(&bound) if g.find(bound) != c => {}
                    _ => {
                        let mut b = binds.clone();
                        b.insert(v.clone(), c);
                        sink.push(b);
                    }
                }
            }
            Pattern::App(name, kids) => {
                let Some(sym) = g.find_sym(name) else { return };
                let nodes = g.nodes_with_sym(c, sym, kids.len());
                for node in nodes {
                    let mut states = vec![binds.clone()];
                    for (kid_pat, &kid_cls) in kids.iter().zip(&node.children) {
                        let mut next = Vec::new();
                        for s in &states {
                            match_pattern(g, kid_pat, kid_cls, s, &mut next);
                        }
                        states = next;
                        if states.is_empty() {
                            break;
                        }
                    }
                    sink.extend(states);
                }
            }
        }
    }

    pub fn instantiate(g: &mut EGraph, pattern: &Pattern, binds: &Bindings) -> ClassId {
        match pattern {
            Pattern::Var(v) => {
                *binds.get(v).unwrap_or_else(|| panic!("unbound var ?{v}"))
            }
            Pattern::App(name, kids) => {
                let children: Vec<ClassId> =
                    kids.iter().map(|k| instantiate(g, k, binds)).collect();
                let sym = g.sym(name);
                g.add(ENode { sym, children })
            }
        }
    }

    pub struct Runner {
        pub iter_limit: usize,
        pub node_limit: usize,
        pub match_limit: usize,
    }

    impl Runner {
        pub fn run(&self, g: &mut EGraph, rules: &[Rewrite]) -> usize {
            let mut iterations = 0;
            for _ in 0..self.iter_limit {
                iterations += 1;
                if !self.run_one(g, rules) {
                    break;
                }
                if g.node_count() > self.node_limit {
                    break;
                }
            }
            iterations
        }

        fn run_one(&self, g: &mut EGraph, rules: &[Rewrite]) -> bool {
            let mut any_change = false;
            for rule in rules.iter() {
                let classes = g.class_ids();
                let mut matches: Vec<(ClassId, Bindings)> = Vec::new();
                'collect: for c in classes {
                    let mut sink = Vec::new();
                    match_pattern(g, &rule.lhs, c, &HashMap::new(), &mut sink);
                    for b in sink {
                        matches.push((c, b));
                        if matches.len() >= self.match_limit {
                            break 'collect;
                        }
                    }
                }
                let mut rule_changed = false;
                for (c, binds) in matches {
                    let replacement = match &rule.action {
                        Action::Template(rhs) => Some(instantiate(g, rhs, &binds)),
                        Action::Dynamic(f) => f(g, &binds),
                    };
                    if let Some(r) = replacement {
                        let before = g.find(c);
                        let after = g.find(r);
                        if before != after {
                            g.union(c, r);
                            any_change = true;
                            rule_changed = true;
                        }
                    }
                    if g.node_count() > self.node_limit {
                        g.rebuild();
                        return any_change;
                    }
                }
                if rule_changed {
                    g.rebuild();
                }
            }
            any_change
        }
    }

    fn const_of(g: &mut EGraph, c: ClassId) -> Option<i64> {
        for n in g.nodes(c) {
            let name = g.sym_name(n.sym).to_string();
            if let Some(v) = name.strip_prefix("const:") {
                if let Ok(k) = v.parse::<i64>() {
                    return Some(k);
                }
            }
        }
        None
    }

    /// The internal rule set over legacy engine types. The pattern→pattern
    /// rules come from the library's shared `SIMPLE_RULES` table, so both
    /// engines always saturate the same rule set; only the dynamic
    /// closures are duplicated (they are engine-typed).
    pub fn internal_rules() -> Vec<Rewrite> {
        let mut rules: Vec<Rewrite> = aquas::compiler::rules::SIMPLE_RULES
            .iter()
            .map(|&(n, l, r)| Rewrite::simple(n, l, r))
            .collect();
        rules.push(Rewrite::dynamic("shl-to-mul", "(shl ?x ?c)", |g, binds| {
            let k = const_of(g, binds["c"])?;
            if !(0..=32).contains(&k) {
                return None;
            }
            let x = binds["x"];
            let cm = g.add_named(&format!("const:{}", 1i64 << k), vec![]);
            Some(g.add_named("mul", vec![x, cm]))
        }));
        rules.push(Rewrite::dynamic("shr-to-div", "(shr ?x ?c)", |g, binds| {
            let k = const_of(g, binds["c"])?;
            if !(1..=32).contains(&k) {
                return None;
            }
            let x = binds["x"];
            let cm = g.add_named(&format!("const:{}", 1i64 << k), vec![]);
            Some(g.add_named("div", vec![x, cm]))
        }));
        rules.push(Rewrite::dynamic("fold-add", "(add ?a ?b)", |g, binds| {
            let x = const_of(g, binds["a"])?;
            let y = const_of(g, binds["b"])?;
            Some(g.add_named(&format!("const:{}", x.wrapping_add(y)), vec![]))
        }));
        rules.push(Rewrite::dynamic("fold-mul", "(mul ?a ?b)", |g, binds| {
            let x = const_of(g, binds["a"])?;
            let y = const_of(g, binds["b"])?;
            Some(g.add_named(&format!("const:{}", x.wrapping_mul(y)), vec![]))
        }));
        rules.push(Rewrite::dynamic("mask-to-rem", "(and ?x ?c)", |g, binds| {
            let k = const_of(g, binds["c"])?;
            if k <= 0 || (k + 1) & k != 0 {
                return None;
            }
            let x = binds["x"];
            let cm = g.add_named(&format!("const:{}", k + 1), vec![]);
            Some(g.add_named("rem", vec![x, cm]))
        }));
        rules.push(Rewrite::dynamic("rem-to-mask", "(rem ?x ?c)", |g, binds| {
            let k = const_of(g, binds["c"])?;
            if k <= 1 || k & (k - 1) != 0 {
                return None;
            }
            let x = binds["x"];
            let cm = g.add_named(&format!("const:{}", k - 1), vec![]);
            Some(g.add_named("and", vec![x, cm]))
        }));
        rules
    }
}

/// Scale a term graph to `copies` disjoint kernel-pair instances in one
/// graph — the "many ISAXes and workloads" scenario the engine must
/// sustain. Leaf/buffer symbols (those with a `:`, except shared
/// `const:*` literals) get a per-copy suffix so copies stay disjoint
/// while the rule alphabet (`add`, `mul`, `shl`, …) is untouched.
fn scaled(tg: &TermGraph, copies: usize) -> TermGraph {
    let mut terms = Vec::with_capacity(tg.terms.len() * copies);
    for i in 0..copies {
        let base = (i * tg.terms.len()) as u32;
        for (sym, kids) in &tg.terms {
            let sym = if i > 0 && sym.contains(':') && !sym.starts_with("const:") {
                format!("{sym}@{i}")
            } else {
                sym.clone()
            };
            terms.push((sym, kids.iter().map(|&k| k + base).collect()));
        }
    }
    TermGraph { terms, sw_root: tg.sw_root, isax_root: tg.isax_root }
}

/// Replay a term graph into the legacy engine.
fn replay_legacy(tg: &TermGraph) -> (legacy::EGraph, legacy::ClassId, legacy::ClassId) {
    let mut g = legacy::EGraph::new();
    let mut ids: Vec<legacy::ClassId> = Vec::with_capacity(tg.terms.len());
    for (sym, kids) in &tg.terms {
        let children: Vec<legacy::ClassId> =
            kids.iter().map(|&k| ids[k as usize]).collect();
        ids.push(g.add_named(sym, children));
    }
    (g, ids[tg.sw_root as usize], ids[tg.isax_root as usize])
}

/// Saturate + match on the legacy engine; returns (wall ms, loops equal).
fn run_legacy(tg: &TermGraph) -> (f64, bool) {
    let (mut g, sw, isax) = replay_legacy(tg);
    let rules = legacy::internal_rules();
    let runner =
        legacy::Runner { iter_limit: 12, node_limit: 100_000, match_limit: 10_000 };
    let t0 = Instant::now();
    runner.run(&mut g, &rules);
    let eq = g.find(sw) == g.find(isax);
    (t0.elapsed().as_secs_f64() * 1e3, eq)
}

/// Saturate + match on the current engine; returns (wall ms, loops equal).
fn run_new(tg: &TermGraph) -> (f64, bool) {
    let (mut g, sw, isax) = replay(tg);
    let rules = internal_rules();
    let t0 = Instant::now();
    bench_runner().run(&mut g, &rules);
    let eq = g.find(sw) == g.find(isax);
    (t0.elapsed().as_secs_f64() * 1e3, eq)
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--test");
    let out_path =
        flag_value(&args, "--out").unwrap_or_else(|| "BENCH_egraph.json".to_string());
    let check_path = flag_value(&args, "--check");

    // 1. Current-engine workload report.
    let mut report = aquas::bench_harness::egraph::report(quick);

    // 2. Old-vs-new on the same replayed term graphs, scaled to several
    //    disjoint kernel-pair instances per graph (multi-ISAX programs).
    let samples = if quick { 1 } else { 3 };
    let copies = if quick { 4 } else { 16 };
    for (name, tg) in
        [("gf2mm", gf2mm_term_graph()), ("attention", attention_term_graph())]
    {
        let tg = scaled(&tg, copies);
        let mut legacy_eq = false;
        let legacy_ms = summarize(
            (0..samples)
                .map(|_| {
                    let (ms, eq) = run_legacy(&tg);
                    legacy_eq = eq;
                    ms
                })
                .collect(),
        )
        .mean;
        let mut new_eq = false;
        let new_ms = summarize(
            (0..samples)
                .map(|_| {
                    let (ms, eq) = run_new(&tg);
                    new_eq = eq;
                    ms
                })
                .collect(),
        )
        .mean;
        // Report verdict (dis)agreement as data and finish all measurements
        // before failing: the `--check` gate below turns disagreement into
        // a non-zero exit, so CI catches it with the full JSON uploaded.
        if legacy_eq != new_eq {
            eprintln!(
                "WARNING: engines disagree on {name}: legacy={legacy_eq} new={new_eq} \
                 (match/node caps truncate differently?)"
            );
        }
        let speedup = legacy_ms / new_ms.max(1e-9);
        println!(
            "{name} x{copies}: legacy {legacy_ms:.3} ms, new {new_ms:.3} ms → \
             {speedup:.1}x (saturation+match, loops equal: new={new_eq} \
             legacy={legacy_eq})"
        );
        report.metric(&format!("{name}_scaled_copies"), copies as f64);
        report.metric(&format!("{name}_legacy_saturate_ms"), legacy_ms);
        report.metric(&format!("{name}_speedup_vs_legacy"), speedup);
        report.metric(&format!("{name}_loops_equal_new"), if new_eq { 1.0 } else { 0.0 });
        report.metric(
            &format!("{name}_verdicts_agree"),
            if legacy_eq == new_eq { 1.0 } else { 0.0 },
        );
    }

    println!("\n{}", report.render());

    // 3. JSON report + regression gate.
    std::fs::write(&out_path, report.metrics_json())
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("report written to {out_path}");

    if let Some(baseline_path) = check_path {
        // Gate 1: the two engines must agree on every match verdict (and
        // the new engine must have unified each sw/isax pair).
        for name in ["gf2mm", "attention"] {
            if report.metrics[&format!("{name}_verdicts_agree")] != 1.0
                || report.metrics[&format!("{name}_loops_equal_new")] != 1.0
            {
                eprintln!("VERDICT MISMATCH: see {name}_* metrics in {out_path}");
                std::process::exit(1);
            }
        }
        // Gate 2: saturation wall time vs the checked-in baseline.
        let text = std::fs::read_to_string(&baseline_path)
            .unwrap_or_else(|e| panic!("read baseline {baseline_path}: {e}"));
        let j = aquas::util::json::Json::parse(&text).expect("baseline json parses");
        let base = j
            .get("gf2mm_saturate_ms")
            .and_then(|v| v.as_f64())
            .expect("baseline has gf2mm_saturate_ms");
        let measured = report.metrics["gf2mm_saturate_ms"];
        if measured > 2.0 * base {
            eprintln!(
                "REGRESSION: gf2mm saturation {measured:.3} ms is more than 2x the \
                 baseline {base:.3} ms"
            );
            std::process::exit(1);
        }
        println!(
            "checks ok: verdicts agree on every workload; gf2mm saturation \
             {measured:.3} ms vs {base:.3} ms baseline (gate: 2x)"
        );
    }
}
