//! `cargo bench --bench serve` — paged-KV continuous-batching serving
//! benchmark.
//!
//! Replays the checked-in deterministic trace through the serving engine
//! at batch widths 1 / 4 / 8 (see `bench_harness::serve`), prints the
//! report, writes the raw metrics to `--out` (default `BENCH_serve.json`)
//! and — with `--check <baseline.json>` — enforces the CI gates:
//!
//! - KV block accounting leak-free on every run;
//! - replay determinism (identical tokens + simulated clock);
//! - batch widths never perturb the greedy token streams;
//! - batched aggregate throughput at least `min_batch4_throughput_x`
//!   times the single-stream baseline recorded in the baseline file.
//!
//! The report also carries the multi-core SoC core-scaling curves
//! (1/2/4/8 cores on the heavy-tailed `soc_spec` trace), gated on:
//!
//! - the 1-core SoC bitwise-reproducing the plain engine;
//! - SoC replay determinism and core counts never perturbing tokens;
//! - per-shard KV accounting leak-free at every core count;
//! - 4-core throughput at least `min_cores4_throughput_x` times the
//!   1-core SoC, but scaling strictly sublinear at 2/4/8 cores;
//! - a nonzero shared-DDR contention delta (`dma_cycles`) once the
//!   8-core fleet oversubscribes the DDR port group.
//!
//! And the chaos degradation gates (PR 7):
//!
//! - the empty fault plan is bitwise the plain 4-core run;
//! - with 1 / 2 of 4 cores killed mid-trace, survivors stay leak-free,
//!   lose no requests, keep token streams bitwise, replay
//!   deterministically, and hold throughput above the
//!   `min_deg_dead1_frac` / `min_deg_dead2_frac` floors (fractions of
//!   the healthy 4-core run).
//!
//! `-- --test` is the CI smoke mode (shorter trace).

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--test");
    let out_path =
        flag_value(&args, "--out").unwrap_or_else(|| "BENCH_serve.json".to_string());
    let check_path = flag_value(&args, "--check");

    let report = aquas::bench_harness::serve::report(quick);
    println!("{}", report.render());

    std::fs::write(&out_path, report.metrics_json())
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("report written to {out_path}");

    if let Some(baseline_path) = check_path {
        let mut failed = false;
        // Gate 1: correctness invariants must hold on every replay.
        for (metric, why) in [
            ("replay_deterministic", "trace replay must be deterministic"),
            ("batch4_tokens_match_single", "batching perturbed greedy tokens"),
            ("batch8_tokens_match_single", "batching perturbed greedy tokens"),
            ("single_kv_leak_free", "KV blocks leaked"),
            ("batch4_kv_leak_free", "KV blocks leaked"),
            ("batch8_kv_leak_free", "KV blocks leaked"),
            ("fair4_kv_leak_free", "KV blocks leaked"),
        ] {
            if report.metrics.get(metric) != Some(&1.0) {
                eprintln!("GATE FAILED: {metric} != 1 ({why}); see {out_path}");
                failed = true;
            }
        }
        // Gate 2: batched throughput vs the recorded single-stream bar.
        let text = std::fs::read_to_string(&baseline_path)
            .unwrap_or_else(|e| panic!("read baseline {baseline_path}: {e}"));
        let j = aquas::util::json::Json::parse(&text).expect("baseline json parses");
        let min_x = j
            .get("min_batch4_throughput_x")
            .and_then(|v| v.as_f64())
            .expect("baseline has min_batch4_throughput_x");
        let measured = report.metrics["batch4_throughput_x"];
        if measured < min_x {
            eprintln!(
                "REGRESSION: batch-4 throughput {measured:.2}x the single-stream \
                 baseline is below the recorded floor {min_x:.2}x"
            );
            failed = true;
        }
        // Gate 3: multi-core SoC scaling invariants.
        for (metric, why) in [
            ("soc1_bitwise_match_engine", "1-core SoC diverged from the engine"),
            ("soc_replay_deterministic", "SoC replay must be deterministic"),
            ("cores2_tokens_match_1core", "sharding perturbed greedy tokens"),
            ("cores4_tokens_match_1core", "sharding perturbed greedy tokens"),
            ("cores8_tokens_match_1core", "sharding perturbed greedy tokens"),
            ("cores1_kv_leak_free", "KV shard leaked"),
            ("cores2_kv_leak_free", "KV shard leaked"),
            ("cores4_kv_leak_free", "KV shard leaked"),
            ("cores8_kv_leak_free", "KV shard leaked"),
        ] {
            if report.metrics.get(metric) != Some(&1.0) {
                eprintln!("GATE FAILED: {metric} != 1 ({why}); see {out_path}");
                failed = true;
            }
        }
        let min_soc_x = j
            .get("min_cores4_throughput_x")
            .and_then(|v| v.as_f64())
            .expect("baseline has min_cores4_throughput_x");
        let soc_x4 = report.metrics["cores4_throughput_x"];
        if soc_x4 < min_soc_x {
            eprintln!(
                "REGRESSION: 4-core SoC throughput {soc_x4:.2}x the 1-core SoC is \
                 below the recorded floor {min_soc_x:.2}x"
            );
            failed = true;
        }
        for (cores, linear) in [(2usize, 2.0), (4, 4.0), (8, 8.0)] {
            let x = report.metrics[&format!("cores{cores}_throughput_x")];
            if x >= linear {
                eprintln!(
                    "GATE FAILED: {cores}-core scaling {x:.2}x is not strictly \
                     sublinear (contention/imbalance must be visible)"
                );
                failed = true;
            }
        }
        if report.metrics["cores8_contention_dma_cycles"] <= 0.0 {
            eprintln!(
                "GATE FAILED: 8-core run recorded no shared-DDR contention delta \
                 in dma_cycles"
            );
            failed = true;
        }
        // Gate 4: chaos — fault-free purity and graceful degradation.
        for (metric, why) in [
            ("faults_empty_bitwise", "the empty fault plan perturbed serving"),
            ("deg_dead1_kv_leak_free", "KV shard leaked under a core death"),
            ("deg_dead2_kv_leak_free", "KV shard leaked under two core deaths"),
            ("deg_dead1_accounted", "requests lost under a core death"),
            ("deg_dead2_accounted", "requests lost under two core deaths"),
            ("deg_dead1_tokens_preserved", "failover perturbed surviving tokens"),
            ("deg_dead2_tokens_preserved", "failover perturbed surviving tokens"),
            ("deg_dead1_replay_deterministic", "chaos replay must be deterministic"),
            ("deg_dead2_replay_deterministic", "chaos replay must be deterministic"),
        ] {
            if report.metrics.get(metric) != Some(&1.0) {
                eprintln!("GATE FAILED: {metric} != 1 ({why}); see {out_path}");
                failed = true;
            }
        }
        let mut deg_fracs = [0.0f64; 2];
        for dead in [1usize, 2] {
            let key = format!("min_deg_dead{dead}_frac");
            let floor = j
                .get(&key)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("baseline has {key}"));
            let frac = report.metrics[&format!("deg_dead{dead}_throughput_frac")];
            deg_fracs[dead - 1] = frac;
            if frac < floor {
                eprintln!(
                    "REGRESSION: {dead} dead of 4 cores holds only {frac:.2}x of the \
                     healthy 4-core throughput, below the recorded floor {floor:.2}x"
                );
                failed = true;
            }
        }
        if failed {
            std::process::exit(1);
        }
        println!(
            "checks ok: deterministic + leak-free + token-stable; batch-4 throughput \
             {measured:.2}x single-stream (floor {min_x:.2}x); 4-core SoC {soc_x4:.2}x \
             1-core (floor {min_soc_x:.2}x), sublinear with a nonzero 8-core \
             contention delta; chaos degradation {:.2}x / {:.2}x of healthy at 1 / 2 \
             dead cores with bitwise-clean failover",
            deg_fracs[0], deg_fracs[1]
        );
    }
}
