//! `cargo bench --bench serve` — paged-KV continuous-batching serving
//! benchmark.
//!
//! Replays the checked-in deterministic trace through the serving engine
//! at batch widths 1 / 4 / 8 (see `bench_harness::serve`), prints the
//! report, writes the raw metrics to `--out` (default `BENCH_serve.json`)
//! and — with `--check <baseline.json>` — enforces the CI gates:
//!
//! - KV block accounting leak-free on every run;
//! - replay determinism (identical tokens + simulated clock);
//! - batch widths never perturb the greedy token streams;
//! - batched aggregate throughput at least `min_batch4_throughput_x`
//!   times the single-stream baseline recorded in the baseline file.
//!
//! `-- --test` is the CI smoke mode (shorter trace).

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--test");
    let out_path =
        flag_value(&args, "--out").unwrap_or_else(|| "BENCH_serve.json".to_string());
    let check_path = flag_value(&args, "--check");

    let report = aquas::bench_harness::serve::report(quick);
    println!("{}", report.render());

    std::fs::write(&out_path, report.metrics_json())
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("report written to {out_path}");

    if let Some(baseline_path) = check_path {
        let mut failed = false;
        // Gate 1: correctness invariants must hold on every replay.
        for (metric, why) in [
            ("replay_deterministic", "trace replay must be deterministic"),
            ("batch4_tokens_match_single", "batching perturbed greedy tokens"),
            ("batch8_tokens_match_single", "batching perturbed greedy tokens"),
            ("single_kv_leak_free", "KV blocks leaked"),
            ("batch4_kv_leak_free", "KV blocks leaked"),
            ("batch8_kv_leak_free", "KV blocks leaked"),
            ("fair4_kv_leak_free", "KV blocks leaked"),
        ] {
            if report.metrics.get(metric) != Some(&1.0) {
                eprintln!("GATE FAILED: {metric} != 1 ({why}); see {out_path}");
                failed = true;
            }
        }
        // Gate 2: batched throughput vs the recorded single-stream bar.
        let text = std::fs::read_to_string(&baseline_path)
            .unwrap_or_else(|e| panic!("read baseline {baseline_path}: {e}"));
        let j = aquas::util::json::Json::parse(&text).expect("baseline json parses");
        let min_x = j
            .get("min_batch4_throughput_x")
            .and_then(|v| v.as_f64())
            .expect("baseline has min_batch4_throughput_x");
        let measured = report.metrics["batch4_throughput_x"];
        if measured < min_x {
            eprintln!(
                "REGRESSION: batch-4 throughput {measured:.2}x the single-stream \
                 baseline is below the recorded floor {min_x:.2}x"
            );
            failed = true;
        }
        if failed {
            std::process::exit(1);
        }
        println!(
            "checks ok: deterministic + leak-free + token-stable; batch-4 throughput \
             {measured:.2}x single-stream (floor {min_x:.2}x)"
        );
    }
}
