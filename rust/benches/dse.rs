//! `cargo bench --bench dse` — automated design-space exploration.
//!
//! Runs the deterministic Pareto search over interface width × burst ×
//! in-flight × SRAM banks × FU-mix unroll, each candidate priced by the
//! real pipeline (budgeted mid-end → synthesis → hwgen census → dmasim
//! schedule replay) jointly over gf2mm / attention / pqc / pcp (see
//! `bench_harness::dse`). Writes the raw metrics to `--out` (default
//! `BENCH_dse.json`) and — with `--check` — enforces the CI gates:
//!
//! - the frontier is bitwise deterministic across a same-seed replay;
//! - the frontier is mutually non-dominated;
//! - the frontier weakly dominates every hand-picked §6.1 config;
//! - growing the area budget never worsens the best-cycles point.
//!
//! `-- --test` is the CI smoke mode (exhaustive over the trimmed demo
//! space instead of the sampled full space).

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--test");
    let out_path = flag_value(&args, "--out").unwrap_or_else(|| "BENCH_dse.json".to_string());
    let check = args.iter().any(|a| a == "--check");

    let report = aquas::bench_harness::dse::report(quick);
    println!("{}", report.render());

    std::fs::write(&out_path, report.metrics_json())
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("report written to {out_path}");

    if check {
        let mut failed = false;
        for (metric, why) in [
            (
                "frontier_deterministic",
                "a same-seed replay diverged bitwise — the search lost determinism",
            ),
            (
                "frontier_mutually_nondominated",
                "a frontier member weakly dominates another — the Pareto filter broke",
            ),
            (
                "frontier_covers_handpicked",
                "a hand-picked §6.1 config escaped the frontier — the search no \
                 longer beats (or matches) hand tuning",
            ),
            (
                "monotone_area_budget",
                "growing the area budget worsened the best-cycles point",
            ),
        ] {
            if report.metrics.get(metric) != Some(&1.0) {
                eprintln!("GATE FAILED: {metric} != 1 ({why}); see {out_path}");
                failed = true;
            }
        }
        if failed {
            std::process::exit(1);
        }
        println!(
            "checks ok: deterministic frontier of {} points over {} evaluated \
             candidates; covers both §6.1 hand-picked configs \
             (best speedup vs default {:.2}x); area-budget monotone",
            report.metrics["frontier_size"],
            report.metrics["evaluated_points"],
            report.metrics.get("best_speedup_vs_handpicked").copied().unwrap_or(f64::NAN),
        );
    }
}
