//! `cargo bench --bench fig8_llm` — regenerates Figure 8 (LLM inference TTFT/ITL + FPGA resources)
//! and reports harness runtime statistics (criterion is unavailable in
//! the offline vendor set; see DESIGN.md).

use std::time::Instant;

fn main() {
    // Warm-up + timed repetitions of the full harness.
    let mut samples = Vec::new();
    let mut last = None;
    for _ in 0..20 {
        let t0 = Instant::now();
        let r = aquas::bench_harness::fig8();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
        last = Some(r);
    }
    println!("{}", last.unwrap().render());
    let s = aquas::util::stats::summarize(samples);
    println!(
        "harness runtime: mean {:.2} ms  p50 {:.2} ms  p95 {:.2} ms  (n={})",
        s.mean, s.p50, s.p95, s.n
    );
}
