"""L2: Llama-2-style transformer forward (prefill + KV-cache decode) in JAX.

This is the compute graph for the paper's CPU-LLM-inference case study
(§6.5).  The attention hot-spot calls the L1 Pallas kernel
(`kernels.attention.mha`); everything else is plain jnp so XLA fuses it.

The model is deliberately parameterizable: the cycle-level study on the Rust
side models the paper's Llama-2 110M int8 configuration, while the *real*
numeric run (AOT artifact executed through PJRT by the Rust coordinator)
uses a reduced configuration so interpret-mode Pallas stays fast on CPU.

Weights are materialized at AOT time from a fixed PRNG seed and baked into
the lowered HLO as constants — the Rust side only feeds token ids and the
KV cache, keeping the request path free of Python and of weight plumbing.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Llama-style architecture hyperparameters."""

    vocab: int = 256
    dim: int = 64
    n_layers: int = 2
    n_heads: int = 4
    hidden: int = 160  # SwiGLU inner dim
    max_seq: int = 64
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads

    def param_count(self) -> int:
        """Total parameter count (for the cycle model's 110M configuration)."""
        per_layer = (
            4 * self.dim * self.dim  # wq wk wv wo
            + 3 * self.dim * self.hidden  # w1 w2 w3
            + 2 * self.dim  # norms
        )
        return self.vocab * self.dim * 2 + self.n_layers * per_layer + self.dim


# Paper configuration: Llama-2 110M-class (dim 768, 12 layers, 12 heads).
PAPER_CONFIG = ModelConfig(
    vocab=32000, dim=768, n_layers=12, n_heads=12, hidden=2048, max_seq=1024
)
# Reduced configuration for the real PJRT run (interpret-mode friendly).
TINY_CONFIG = ModelConfig()


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, Any]:
    """Materialize all weights from a fixed seed (baked into the AOT HLO)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3 + cfg.n_layers)
    scale = 0.02

    def dense(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(jnp.float32)

    params: dict[str, Any] = {
        "embed": dense(ks[0], (cfg.vocab, cfg.dim)),
        "unembed": dense(ks[1], (cfg.dim, cfg.vocab)),
        "final_norm": jnp.ones((cfg.dim,), jnp.float32),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        lk = jax.random.split(ks[3 + i], 8)
        params["layers"].append(
            {
                "attn_norm": jnp.ones((cfg.dim,), jnp.float32),
                "mlp_norm": jnp.ones((cfg.dim,), jnp.float32),
                "wq": dense(lk[0], (cfg.dim, cfg.dim)),
                "wk": dense(lk[1], (cfg.dim, cfg.dim)),
                "wv": dense(lk[2], (cfg.dim, cfg.dim)),
                "wo": dense(lk[3], (cfg.dim, cfg.dim)),
                "w1": dense(lk[4], (cfg.dim, cfg.hidden)),
                "w2": dense(lk[5], (cfg.hidden, cfg.dim)),
                "w3": dense(lk[6], (cfg.dim, cfg.hidden)),
            }
        )
    return params


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [B,H,T,Dh], positions: [T] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def _block(
    cfg: ModelConfig,
    layer: dict[str, Any],
    x: jax.Array,
    positions: jax.Array,
    kv: tuple[jax.Array, jax.Array] | None,
    *,
    use_pallas: bool,
):
    """One transformer block. Returns (x, (k_full, v_full))."""
    h = rmsnorm(x, layer["attn_norm"])
    q = _split_heads(h @ layer["wq"], cfg.n_heads)
    k = _split_heads(h @ layer["wk"], cfg.n_heads)
    v = _split_heads(h @ layer["wv"], cfg.n_heads)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if kv is not None:
        k_cache, v_cache = kv  # [B,H,Tpast,Dh]
        k = jnp.concatenate([k_cache, k], axis=2)
        v = jnp.concatenate([v_cache, v], axis=2)

    if use_pallas and q.shape[2] > 1:
        attn = attention.mha(q, k, v, causal=True)
    else:
        # Decode step (Tq=1): every cached position is visible, plain path.
        from .kernels import ref

        attn = ref.mha(q, k, v, causal=q.shape[2] > 1)
    x = x + _merge_heads(attn) @ layer["wo"]

    h = rmsnorm(x, layer["mlp_norm"])
    gated = jax.nn.silu(h @ layer["w1"]) * (h @ layer["w3"])
    return x + gated @ layer["w2"], (k, v)


def prefill(
    cfg: ModelConfig, params: dict[str, Any], ids: jax.Array, *, use_pallas: bool = True
):
    """Full-sequence forward. ids: [B,T] int32.

    Returns (logits [B,T,V], k_caches [L,B,H,T,Dh], v_caches [L,B,H,T,Dh]).
    """
    b, t = ids.shape
    positions = jnp.arange(t, dtype=jnp.int32)
    x = params["embed"][ids]
    ks, vs = [], []
    for layer in params["layers"]:
        x, (k, v) = _block(cfg, layer, x, positions, None, use_pallas=use_pallas)
        ks.append(k)
        vs.append(v)
    x = rmsnorm(x, params["final_norm"])
    logits = x @ params["unembed"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_step(
    cfg: ModelConfig,
    params: dict[str, Any],
    ids: jax.Array,
    k_caches: jax.Array,
    v_caches: jax.Array,
    pos: jax.Array,
):
    """Single-token decode. ids: [B,1] int32; caches: [L,B,H,Tpast,Dh]; pos: [] int32.

    The cache is *exact-sized*: prefill returns length-T caches and each
    decode step grows them by one, so every cached slot is valid and the
    attention is unmasked.  `pos` is the absolute position of the new token
    (used for RoPE).  Returns (logits [B,V], k_caches', v_caches').
    """
    positions = pos[None].astype(jnp.int32)
    x = params["embed"][ids]
    new_ks, new_vs = [], []
    for i, layer in enumerate(params["layers"]):
        x, (k, v) = _block(
            cfg, layer, x, positions, (k_caches[i], v_caches[i]), use_pallas=False
        )
        new_ks.append(k)
        new_vs.append(v)
    x = rmsnorm(x, params["final_norm"])
    logits = (x @ params["unembed"])[:, 0]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def prefill_fixed(cfg: ModelConfig, params: dict[str, Any], ids: jax.Array):
    """Prefill that returns max_seq-sized caches (zero-padded past T).

    This is the AOT entry point: HLO needs static shapes, so the serving
    runtime works with a fixed-capacity KV cache and a scalar `pos` cursor.
    Returns (logits [B,T,V], k_caches [L,B,H,max_seq,Dh], v_caches ...).
    """
    logits, ks, vs = prefill(cfg, params, ids)
    pad_t = cfg.max_seq - ks.shape[3]
    pad = ((0, 0), (0, 0), (0, 0), (0, pad_t), (0, 0))
    return logits, jnp.pad(ks, pad), jnp.pad(vs, pad)


def decode_step_fixed(
    cfg: ModelConfig,
    params: dict[str, Any],
    ids: jax.Array,
    k_caches: jax.Array,
    v_caches: jax.Array,
    pos: jax.Array,
):
    """Single-token decode against a fixed-capacity cache.

    ids: [B,1] int32; caches: [L,B,H,max_seq,Dh] f32 with entries < pos
    valid; pos: [] int32 = absolute position of the new token.  The new
    token's K/V are written at slot `pos`; attention masks slots > pos.
    Returns (logits [B,V], k_caches', v_caches').
    """
    positions = pos[None].astype(jnp.int32)
    x = params["embed"][ids]
    tmax = k_caches.shape[3]
    slot_ids = jnp.arange(tmax)
    new_ks, new_vs = [], []
    for i, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"])
        q = _split_heads(h @ layer["wq"], cfg.n_heads)
        k = _split_heads(h @ layer["wk"], cfg.n_heads)
        v = _split_heads(h @ layer["wv"], cfg.n_heads)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(
            k_caches[i], k, (0, 0, pos.astype(jnp.int32), 0)
        )
        vc = jax.lax.dynamic_update_slice(
            v_caches[i], v, (0, 0, pos.astype(jnp.int32), 0)
        )
        scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.head_dim, jnp.float32))
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, kc) * scale
        mask = slot_ids[None, None, None, :] <= pos
        scores = jnp.where(mask, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bhqd", probs, vc)
        x = x + _merge_heads(attn) @ layer["wo"]
        h = rmsnorm(x, layer["mlp_norm"])
        x = x + (jax.nn.silu(h @ layer["w1"]) * (h @ layer["w3"])) @ layer["w2"]
        new_ks.append(kc)
        new_vs.append(vc)
    x = rmsnorm(x, params["final_norm"])
    logits = (x @ params["unembed"])[:, 0]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def build(cfg: ModelConfig = TINY_CONFIG, seed: int = 0):
    """Convenience: params + jitted prefill/decode closures over baked weights."""
    params = init_params(cfg, seed)

    @jax.jit
    def run_prefill(ids):
        return prefill(cfg, params, ids)

    @jax.jit
    def run_decode(ids, k_caches, v_caches, pos):
        return decode_step(cfg, params, ids, k_caches, v_caches, pos)

    return params, run_prefill, run_decode
