"""AOT bridge: lower every L2/L1 entry point to HLO *text* + a manifest.

Run once at build time (`make artifacts`); Python never appears on the
request path.  The Rust runtime (`rust/src/runtime/`) loads each
`artifacts/<name>.hlo.txt` with `HloModuleProto::from_text_file`, compiles
it on the PJRT CPU client, and executes it.

HLO text — NOT `lowered.compile().serialize()` — is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly.

Every entry is lowered with `return_tuple=True`, so the Rust side always
unwraps a tuple (even for single outputs).  `artifacts/manifest.json`
records arg/output shapes+dtypes so the runtime can typecheck calls.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import attention, gf2, graphics, pointcloud


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dt(x) -> str:
    return jnp.dtype(x.dtype).name


def _entry(fn, args) -> tuple[str, list[dict], list[dict]]:
    """Lower fn(*args) -> (hlo_text, arg_manifest, out_manifest)."""
    lowered = jax.jit(fn).lower(*args)
    outs = jax.eval_shape(fn, *args)
    flat_outs, _ = jax.tree.flatten(outs)
    arg_m = [{"shape": list(a.shape), "dtype": _dt(a)} for a in args]
    out_m = [{"shape": list(o.shape), "dtype": _dt(o)} for o in flat_outs]
    return to_hlo_text(lowered), arg_m, out_m


# --------------------------------------------------------------------------
# Entry-point catalogue.  Shapes here define the serving configuration the
# Rust coordinator is built against (see rust/src/runtime/manifest.rs).
# --------------------------------------------------------------------------

CFG = model.TINY_CONFIG
PREFILL_LEN = 16
BATCH = 1


def build_entries() -> dict[str, tuple]:
    params = model.init_params(CFG, seed=0)
    l, b, h = CFG.n_layers, BATCH, CFG.n_heads
    tmax, dh = CFG.max_seq, CFG.head_dim

    def llm_prefill(ids):
        return model.prefill_fixed(CFG, params, ids)

    def llm_decode(ids, kc, vc, pos):
        return model.decode_step_fixed(CFG, params, ids, kc, vc, pos[0])

    cache = _spec((l, b, h, tmax, dh))
    return {
        # LLM case study (§6.5): the real serving path.
        "llm_prefill": (llm_prefill, [_spec((b, PREFILL_LEN), jnp.int32)]),
        "llm_decode": (
            llm_decode,
            [_spec((b, 1), jnp.int32), cache, cache, _spec((1,), jnp.int32)],
        ),
        # Standalone ISAX datapath golden models.  The Rust ISAX execution
        # engine checks its numerics against these artifacts in tests.
        "attention": (
            lambda q, k, v: (attention.mha(q, k, v),),
            [_spec((1, 4, 64, 16))] * 3,
        ),
        "gf2mm": (
            lambda a, bb: (gf2.gf2mm(a, bb),),
            [_spec((64, 64), jnp.int32)] * 2,
        ),
        "vdecomp": (
            lambda w: (gf2.vdecomp(w, 512),),
            [_spec((16,), jnp.int32)],
        ),
        "vdist3": (
            lambda p, q: (pointcloud.vdist3(p, q),),
            [_spec((256, 3))] * 2,
        ),
        "mcov": (
            lambda p, q: (pointcloud.mcov(p, q),),
            [_spec((256, 3))] * 2,
        ),
        "vfsmax": (lambda x: pointcloud.vfsmax(x), [_spec((256,))]),
        "vmadot": (
            lambda m, v: (pointcloud.vmadot(m, v),),
            [_spec((64, 64)), _spec((64,))],
        ),
        "phong": (
            lambda n, li, v: (graphics.phong(n, li, v),),
            [_spec((256, 3))] * 3,
        ),
        "vrgb2yuv": (lambda x: (graphics.vrgb2yuv(x),), [_spec((256, 3))]),
        "vmvar": (lambda x: graphics.vmvar(x), [_spec((64, 16))]),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", nargs="*", help="subset of entry names")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest: dict = {
        "model": {
            "vocab": CFG.vocab,
            "dim": CFG.dim,
            "n_layers": CFG.n_layers,
            "n_heads": CFG.n_heads,
            "head_dim": CFG.head_dim,
            "hidden": CFG.hidden,
            "max_seq": CFG.max_seq,
            "prefill_len": PREFILL_LEN,
            "batch": BATCH,
            "param_count": CFG.param_count(),
        },
        "entries": {},
    }
    for name, (fn, specs) in build_entries().items():
        if args.only and name not in args.only:
            continue
        text, arg_m, out_m = _entry(fn, specs)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest["entries"][name] = {
            "file": path.name,
            "args": arg_m,
            "outputs": out_m,
        }
        print(f"wrote {path} ({len(text)} chars, {len(arg_m)} args, {len(out_m)} outs)")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
