"""Flash-style blocked multi-head attention as a Pallas kernel (L1).

This is the functional model of the attention ISAX datapath from the paper's
CPU-LLM-inference case study (§6.5).  The kernel is blocked for VMEM the way
the paper's ISAX stages tiles through its scratchpad:

- the grid walks (batch, head, q-block); each program owns one q tile
  resident in VMEM (the "warm" scratchpad in Aquas cache_hint terms);
- K/V are streamed through the kernel in `block_k`-sized chunks with an
  online-softmax accumulator, mirroring the "cold" DRAM-resident stream the
  Aquas synthesis flow routes over the wide system-bus interface;
- accumulation is f32 regardless of input dtype (MXU-friendly).

`interpret=True` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _attention_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    block_q: int,
    block_k: int,
    seq_k: int,
    causal: bool,
    q_offset_blocks: int,
):
    """One (batch, head, q-block) program: online-softmax over k chunks."""
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)  # [block_q, dh]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    kk = k_ref[0, 0].astype(jnp.float32)  # [seq_k, dh]
    vv = v_ref[0, 0].astype(jnp.float32)  # [seq_k, dh]

    num_kb = seq_k // block_k
    q_pos = (qi + q_offset_blocks) * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        acc, m, l = carry
        kj = jax.lax.dynamic_slice_in_dim(kk, j * block_k, block_k, axis=0)
        vj = jax.lax.dynamic_slice_in_dim(vv, j * block_k, block_k, axis=0)
        s = (q @ kj.T) * scale  # [block_q, block_k]
        if causal:
            k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # Rows that are still fully masked keep m == -inf; exp(-inf - -inf)
        # would be NaN, so guard the correction factor.
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_new))
        p = jnp.exp(s - jnp.where(jnp.isneginf(m_new), 0.0, m_new)[:, None])
        p = jnp.where(jnp.isneginf(s), 0.0, p)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ vj
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows produce zeros
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 32,
    block_k: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """Blocked flash attention. q: [B,H,Tq,Dh]; k,v: [B,H,Tk,Dh] -> [B,H,Tq,Dh].

    Supports Tq != Tk (decode: Tq=1 block with right-aligned causal mask when
    Tq divides evenly; for KV-cache decode the model calls with causal=False
    and a pre-truncated cache instead).
    """
    b, h, tq, dh = q.shape
    tk = k.shape[2]
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    if tq % block_q != 0 or tk % block_k != 0:
        raise ValueError(f"seq lens ({tq},{tk}) must divide blocks ({block_q},{block_k})")
    if causal and (tk - tq) % block_q != 0:
        raise ValueError("causal offset must be a multiple of block_q")
    q_offset_blocks = (tk - tq) // block_q if causal else 0

    grid = (b, h, tq // block_q)
    kernel = functools.partial(
        _attention_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_k=tk,
        causal=causal,
        q_offset_blocks=q_offset_blocks,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, tk, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, tk, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
