"""Pallas kernels for the point-cloud-processing ISAXs (§6.3).

Functional models of the four ICP datapaths: ``vdist3.vv`` (Euclidean
distance), ``mcov.vs`` (cross-covariance), ``vfsmax`` (max+argmax) and
``vmadot`` (matrix-vector multiply).  Point data is laid out [N, 4]
(xyz + pad) so rows are 16-byte aligned — the same padding the Aquas
interface canonicalization step introduces to keep bus transactions legal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad4(p: jax.Array) -> jax.Array:
    """[N,3] -> [N,4] zero-padded (alignment; see module docstring)."""
    return jnp.pad(p, ((0, 0), (0, 1)))


def _vdist3_kernel(p_ref, q_ref, o_ref):
    d = p_ref[...] - q_ref[...]  # [block, 4]; pad lane is zero
    o_ref[...] = jnp.sum(d * d, axis=-1)


def vdist3(p: jax.Array, q: jax.Array, *, block: int = 64, interpret: bool = True) -> jax.Array:
    """Squared distances between paired 3-D points. p,q: [N,3] f32 -> [N] f32."""
    n = p.shape[0]
    block = min(block, n)
    if n % block:
        raise ValueError(f"N={n} must divide block={block}")
    return pl.pallas_call(
        _vdist3_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), p.dtype),
        interpret=interpret,
    )(_pad4(p), _pad4(q))


def _mcov_kernel(p_ref, q_ref, pm_ref, qm_ref, o_ref, *, nsteps: int):
    """Accumulate centered cross-covariance over point blocks."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    pc = p_ref[...] - pm_ref[...]  # [block, 4] minus broadcast mean [1, 4]
    qc = q_ref[...] - qm_ref[...]
    o_ref[...] += pc.T @ qc  # [4, 4]; pad row/col stay zero


def mcov(p: jax.Array, q: jax.Array, *, block: int = 64, interpret: bool = True) -> jax.Array:
    """Cross-covariance sum_i (p_i - p̄)(q_i - q̄)^T. p,q: [N,3] -> [3,3]."""
    n = p.shape[0]
    block = min(block, n)
    if n % block:
        raise ValueError(f"N={n} must divide block={block}")
    pm = jnp.mean(_pad4(p), axis=0, keepdims=True)
    qm = jnp.mean(_pad4(q), axis=0, keepdims=True)
    nsteps = n // block
    kernel = functools.partial(_mcov_kernel, nsteps=nsteps)
    out = pl.pallas_call(
        kernel,
        grid=(nsteps,),
        in_specs=[
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((4, 4), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((4, 4), p.dtype),
        interpret=interpret,
    )(_pad4(p), _pad4(q), pm, qm)
    return out[:3, :3]


def _vfsmax_kernel(x_ref, mx_ref, am_ref, *, block: int, nsteps: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        mx_ref[...] = jnp.full_like(mx_ref, -jnp.inf)
        am_ref[...] = jnp.zeros_like(am_ref)

    x = x_ref[...]
    i = pl.program_id(0)
    local_max = jnp.max(x)
    local_arg = jnp.argmax(x).astype(jnp.int32) + i * block
    cur = mx_ref[0]
    better = local_max > cur
    mx_ref[0] = jnp.where(better, local_max, cur)
    am_ref[0] = jnp.where(better, local_arg, am_ref[0])


def vfsmax(x: jax.Array, *, block: int = 64, interpret: bool = True):
    """Max + argmax of a float vector. x: [N] -> (f32[1], i32[1])."""
    n = x.shape[0]
    block = min(block, n)
    if n % block:
        raise ValueError(f"N={n} must divide block={block}")
    nsteps = n // block
    kernel = functools.partial(_vfsmax_kernel, block=block, nsteps=nsteps)
    mx, am = pl.pallas_call(
        kernel,
        grid=(nsteps,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    return mx[0], am[0]


def _vmadot_kernel(m_ref, v_ref, o_ref):
    o_ref[...] = m_ref[...] @ v_ref[...]


def vmadot(m: jax.Array, v: jax.Array, *, block_r: int = 32, interpret: bool = True) -> jax.Array:
    """Matrix-vector product. m: [R,C] f32, v: [C] -> [R]."""
    r, c = m.shape
    block_r = min(block_r, r)
    if r % block_r:
        raise ValueError(f"R={r} must divide block_r={block_r}")
    return pl.pallas_call(
        _vmadot_kernel,
        grid=(r // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), m.dtype),
        interpret=interpret,
    )(m, v)
