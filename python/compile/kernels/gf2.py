"""Pallas kernels for the post-quantum-cryptography ISAXs (§6.2).

Two datapaths from the paper's syndrome computation s = H e^T over GF(2):

- ``vdecomp``: bitstream unpacking — packed 32-bit words to a {0,1} vector.
  The ISAX reads one word from the scratchpad and fans 32 bits out per
  cycle; here the same fan-out is a vectorized shift/mask over a block.
- ``gf2mm``: matrix multiply over GF(2) — formulated as an *integer* blocked
  matmul followed by a parity reduction (``& 1``) so the MXU-style dot path
  applies; hardware does the same with XOR-popcount trees.

Both run ``interpret=True`` (CPU-PJRT compatible lowering).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vdecomp_kernel(w_ref, o_ref, *, block_bits: int):
    """Unpack one block of bits: each program owns block_bits/32 words."""
    words = w_ref[...]  # [block_bits // 32] int32
    idx = jax.lax.iota(jnp.int32, block_bits)
    w = words[idx // 32]
    o_ref[...] = (w >> (idx % 32)) & 1


def vdecomp(
    words: jax.Array, nbits: int, *, block_bits: int = 256, interpret: bool = True
) -> jax.Array:
    """Unpack packed little-endian bits. words: [nbits/32] int32 -> [nbits] int32."""
    if nbits % 32 != 0:
        raise ValueError("nbits must be a multiple of 32")
    block_bits = min(block_bits, nbits)
    if nbits % block_bits != 0 or block_bits % 32 != 0:
        raise ValueError("block_bits must divide nbits and be a multiple of 32")
    grid = (nbits // block_bits,)
    kernel = functools.partial(_vdecomp_kernel, block_bits=block_bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_bits // 32,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_bits,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nbits,), jnp.int32),
        interpret=interpret,
    )(words)


def _gf2mm_kernel(a_ref, b_ref, o_ref, *, nsteps: int, block_k: int):
    """Blocked integer matmul with parity output."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _finish():
        o_ref[...] &= 1


def gf2mm(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 32,
    block_n: int = 32,
    block_k: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """GF(2) matmul. a: [M,K] {0,1} int32, b: [K,N] -> [M,N] {0,1} int32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(f"dims ({m},{n},{k}) must divide blocks")
    nsteps = k // block_k
    grid = (m // block_m, n // block_n, nsteps)
    kernel = functools.partial(_gf2mm_kernel, nsteps=nsteps, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a.astype(jnp.int32), b.astype(jnp.int32))
