"""Pallas kernels for the graphics-rendering ISAXs (§6.4).

Functional models of the three graphics datapaths the paper pits against the
Saturn vector unit: ``vmvar`` (1st/2nd vector moments), ``mphong`` (Phong
lighting) and ``vrgb2yuv`` (color-space conversion).  All are elementwise or
small-reduction shapes — exactly the class where the paper reports RVV-style
units pay a large area/frequency tax for little benefit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import RGB2YUV


def _phong_kernel(n_ref, l_ref, v_ref, o_ref, *, ka, kd, ks, shininess):
    n = n_ref[...]  # [block, 4], pad lane zero
    l = l_ref[...]
    v = v_ref[...]
    ndotl = jnp.maximum(jnp.sum(n * l, axis=-1), 0.0)
    refl = 2.0 * ndotl[:, None] * n - l
    rdotv = jnp.maximum(jnp.sum(refl * v, axis=-1), 0.0)
    # Specular is gated on a front-facing normal (standard Phong).
    spec = jnp.where(ndotl > 0.0, jnp.power(rdotv, shininess), 0.0)
    o_ref[...] = ka + kd * ndotl + ks * spec


def phong(
    normal: jax.Array,
    light: jax.Array,
    view: jax.Array,
    *,
    ka: float = 0.1,
    kd: float = 0.7,
    ks: float = 0.4,
    shininess: float = 16.0,
    block: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Phong lighting per pixel. normal/light/view: [N,3] -> intensity [N]."""
    n = normal.shape[0]
    block = min(block, n)
    if n % block:
        raise ValueError(f"N={n} must divide block={block}")
    pad = lambda a: jnp.pad(a, ((0, 0), (0, 1)))
    kernel = functools.partial(_phong_kernel, ka=ka, kd=kd, ks=ks, shininess=shininess)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block, 4), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), normal.dtype),
        interpret=interpret,
    )(pad(normal), pad(light), pad(view))


def _rgb2yuv_kernel(x_ref, m_ref, o_ref):
    o_ref[...] = x_ref[...] @ m_ref[...]


def vrgb2yuv(rgb: jax.Array, *, block: int = 64, interpret: bool = True) -> jax.Array:
    """RGB -> YUV conversion. rgb: [N,3] f32 -> [N,3] f32."""
    n = rgb.shape[0]
    block = min(block, n)
    if n % block:
        raise ValueError(f"N={n} must divide block={block}")
    m = jnp.pad(RGB2YUV.T, ((0, 1), (0, 1)))  # [4,4], pad row/col zero
    out = pl.pallas_call(
        _rgb2yuv_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
            pl.BlockSpec((4, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 4), rgb.dtype),
        interpret=interpret,
    )(jnp.pad(rgb, ((0, 0), (0, 1))), m)
    return out[:, :3]


def _vmvar_kernel(x_ref, mean_ref, var_ref):
    x = x_ref[...]  # [block, W]
    w = x.shape[-1]
    mean = jnp.sum(x, axis=-1) / w
    ex2 = jnp.sum(x * x, axis=-1) / w
    mean_ref[...] = mean
    var_ref[...] = ex2 - mean * mean


def vmvar(x: jax.Array, *, block: int = 32, interpret: bool = True):
    """Row-wise mean and variance. x: [N,W] f32 -> (mean [N], var [N])."""
    n, w = x.shape
    block = min(block, n)
    if n % block:
        raise ValueError(f"N={n} must divide block={block}")
    return pl.pallas_call(
        _vmvar_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block, w), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=interpret,
    )(x)
