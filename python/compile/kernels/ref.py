"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the *golden models*: each ISAX datapath synthesized by the Rust
side (L3) corresponds to one function here, and each Pallas kernel (L1) is
checked against these by pytest/hypothesis at build time.  Nothing in this
file uses Pallas; everything is straight jax.numpy so it can be trusted as
an independent specification.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# LLM inference (case study §6.5): multi-head attention
# ---------------------------------------------------------------------------


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    """Multi-head attention. q,k,v: [B, H, T, Dh] -> [B, H, T, Dh]."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        tq, tk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((tq, tk), bool), k=tk - tq)
        scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


# ---------------------------------------------------------------------------
# Post-quantum cryptography (case study §6.2)
# ---------------------------------------------------------------------------


def gf2mm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Matrix multiply over GF(2). a: [M, K] int32 of {0,1}, b: [K, N] -> [M, N]."""
    return (a.astype(jnp.int32) @ b.astype(jnp.int32)) & 1


def vdecomp(words: jax.Array, nbits: int) -> jax.Array:
    """Bitstream unpacking: packed little-endian 32-bit words -> {0,1} vector.

    words: [ceil(nbits/32)] int32; returns [nbits] int32.
    """
    idx = jnp.arange(nbits)
    w = words[idx // 32]
    return (w >> (idx % 32)) & 1


def syndrome(h_rows: jax.Array, e: jax.Array) -> jax.Array:
    """s = H e^T over GF(2); h_rows: [R, C] {0,1}, e: [C] {0,1} -> [R]."""
    return (h_rows.astype(jnp.int32) @ e.astype(jnp.int32)) & 1


# ---------------------------------------------------------------------------
# Point-cloud processing (case study §6.3)
# ---------------------------------------------------------------------------


def vdist3(p: jax.Array, q: jax.Array) -> jax.Array:
    """Squared Euclidean distance between 3-D point pairs. p,q: [N,3] -> [N]."""
    d = p - q
    return jnp.sum(d * d, axis=-1)


def mcov(p: jax.Array, q: jax.Array) -> jax.Array:
    """Cross-covariance of two centered point sets. p,q: [N,3] -> [3,3].

    cov = sum_i (p_i - mean(p)) (q_i - mean(q))^T
    """
    pc = p - jnp.mean(p, axis=0, keepdims=True)
    qc = q - jnp.mean(q, axis=0, keepdims=True)
    return pc.T @ qc


def vfsmax(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Max value + argmax of a vector (float). x: [N] -> (max, argmax)."""
    return jnp.max(x), jnp.argmax(x).astype(jnp.int32)


def vmadot(m: jax.Array, v: jax.Array) -> jax.Array:
    """Matrix-vector multiply. m: [R, C], v: [C] -> [R]."""
    return m @ v


# ---------------------------------------------------------------------------
# Graphics rendering (case study §6.4)
# ---------------------------------------------------------------------------


def phong(
    normal: jax.Array,
    light: jax.Array,
    view: jax.Array,
    ka: float,
    kd: float,
    ks: float,
    shininess: float,
) -> jax.Array:
    """Phong lighting model per pixel. normal/light/view: [N,3] unit vectors -> [N]."""
    ndotl = jnp.maximum(jnp.sum(normal * light, axis=-1), 0.0)
    refl = 2.0 * ndotl[:, None] * normal - light
    rdotv = jnp.maximum(jnp.sum(refl * view, axis=-1), 0.0)
    spec = jnp.where(ndotl > 0.0, jnp.power(rdotv, shininess), 0.0)
    return ka + kd * ndotl + ks * spec


RGB2YUV = jnp.array(
    [
        [0.299, 0.587, 0.114],
        [-0.14713, -0.28886, 0.436],
        [0.615, -0.51499, -0.10001],
    ],
    dtype=jnp.float32,
)


def vrgb2yuv(rgb: jax.Array) -> jax.Array:
    """Color-space conversion. rgb: [N,3] -> yuv [N,3]."""
    return rgb @ RGB2YUV.T


def vmvar(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """First and second moments of row vectors. x: [N, W] -> (mean [N], var [N])."""
    mean = jnp.mean(x, axis=-1)
    var = jnp.mean(x * x, axis=-1) - mean * mean
    return mean, var
