"""L2 model tests: shapes, prefill/decode consistency, AOT entry sanity."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

CFG = model.ModelConfig(vocab=64, dim=32, n_layers=2, n_heads=2, hidden=64, max_seq=32)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


class TestShapes:
    def test_prefill_shapes(self, params):
        ids = jnp.zeros((1, 8), jnp.int32)
        logits, ks, vs = model.prefill(CFG, params, ids)
        assert logits.shape == (1, 8, CFG.vocab)
        assert ks.shape == (CFG.n_layers, 1, CFG.n_heads, 8, CFG.head_dim)
        assert vs.shape == ks.shape

    def test_prefill_fixed_pads_to_max_seq(self, params):
        ids = jnp.zeros((1, 8), jnp.int32)
        _, ks, vs = model.prefill_fixed(CFG, params, ids)
        assert ks.shape[3] == CFG.max_seq
        # padding region must be zeros
        np.testing.assert_allclose(ks[:, :, :, 8:], 0.0)

    def test_param_count_formula(self):
        params = model.init_params(CFG)
        total = sum(x.size for x in jax.tree.leaves(params))
        assert total == CFG.param_count()

    def test_paper_config_is_110m_class(self):
        # Paper §6.5: "Llama 2 model with 110M parameters"
        assert 80e6 < model.PAPER_CONFIG.param_count() < 140e6


class TestDecodeConsistency:
    def test_decode_matches_prefill_logits(self, params):
        """Greedy decode via fixed cache must equal a full re-prefill."""
        key = jax.random.PRNGKey(1)
        t = 8
        ids = jax.random.randint(key, (1, t), 0, CFG.vocab, jnp.int32)
        logits_full, _, _ = model.prefill(CFG, params, ids)

        # Prefill on the first t-1 tokens, decode token t-1 at position t-1.
        _, ks, vs = model.prefill_fixed(CFG, params, ids[:, : t - 1])
        logits_step, _, _ = model.decode_step_fixed(
            CFG, params, ids[:, t - 1 :], ks, vs, jnp.asarray(t - 1)
        )
        np.testing.assert_allclose(
            logits_step, logits_full[:, -1], rtol=2e-4, atol=2e-4
        )

    def test_multi_step_decode_matches_prefill(self, params):
        ids = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, CFG.vocab, jnp.int32)
        logits_full, _, _ = model.prefill(CFG, params, ids)

        _, ks, vs = model.prefill_fixed(CFG, params, ids[:, :3])
        for step in range(3, 6):
            logits_step, ks, vs = model.decode_step_fixed(
                CFG, params, ids[:, step : step + 1], ks, vs, jnp.asarray(step)
            )
        np.testing.assert_allclose(logits_step, logits_full[:, -1], rtol=5e-4, atol=5e-4)

    def test_cache_slots_written_in_place(self, params):
        ids = jnp.zeros((1, 4), jnp.int32)
        _, ks, vs = model.prefill_fixed(CFG, params, ids)
        _, ks2, _ = model.decode_step_fixed(
            CFG, params, jnp.zeros((1, 1), jnp.int32), ks, vs, jnp.asarray(4)
        )
        # old entries unchanged, new slot filled
        np.testing.assert_allclose(ks2[:, :, :, :4], ks[:, :, :, :4])
        assert float(jnp.abs(ks2[:, :, :, 4]).sum()) > 0.0


class TestPallasPath:
    def test_pallas_vs_ref_prefill(self, params):
        """Prefill through the Pallas attention equals the pure-jnp path."""
        cfg = model.ModelConfig(
            vocab=64, dim=32, n_layers=1, n_heads=2, hidden=64, max_seq=32
        )
        p = model.init_params(cfg, seed=3)
        ids = jax.random.randint(jax.random.PRNGKey(4), (1, 32), 0, cfg.vocab, jnp.int32)
        with_pallas, _, _ = model.prefill(cfg, p, ids, use_pallas=True)
        without, _, _ = model.prefill(cfg, p, ids, use_pallas=False)
        np.testing.assert_allclose(with_pallas, without, rtol=2e-4, atol=2e-4)

    def test_build_closures_jit(self):
        params, run_prefill, run_decode = model.build(CFG, seed=0)
        ids = jnp.zeros((1, 8), jnp.int32)
        logits, ks, vs = run_prefill(ids)
        assert logits.shape == (1, 8, CFG.vocab)
        out, ks2, vs2 = run_decode(ids[:, :1], ks, vs, jnp.asarray(8))
        assert out.shape == (1, CFG.vocab)


class TestRope:
    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 8, 16))
        pos = jnp.arange(8, dtype=jnp.int32)
        rotated = model.rope(x, pos, 10000.0)
        np.testing.assert_allclose(
            jnp.linalg.norm(rotated, axis=-1),
            jnp.linalg.norm(x, axis=-1),
            rtol=1e-5,
        )

    def test_rope_position_zero_is_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 8))
        out = model.rope(x, jnp.zeros((1,), jnp.int32), 10000.0)
        np.testing.assert_allclose(out, x, rtol=1e-6)

    def test_rope_relative_shift(self):
        """Dot products depend only on relative positions."""
        q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 16))
        d01 = jnp.sum(
            model.rope(q, jnp.array([5], jnp.int32), 1e4)
            * model.rope(k, jnp.array([3], jnp.int32), 1e4)
        )
        d02 = jnp.sum(
            model.rope(q, jnp.array([9], jnp.int32), 1e4)
            * model.rope(k, jnp.array([7], jnp.int32), 1e4)
        )
        np.testing.assert_allclose(d01, d02, rtol=1e-4)
