"""Kernel-vs-oracle correctness: every Pallas kernel against ref.py.

This is the CORE correctness signal for L1: the Rust ISAX engine's numerics
are validated against the AOT artifacts, and the artifacts are validated
here against the pure-jnp golden models.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, gf2, graphics, pointcloud, ref

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 1e-5, 1e-5


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


class TestAttention:
    @pytest.mark.parametrize("t", [32, 64, 128])
    def test_causal_matches_ref(self, t):
        q, k, v = (_rand(i, (1, 2, t, 16)) for i in range(3))
        out = attention.mha(q, k, v, causal=True)
        want = ref.mha(q, k, v, causal=True)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_non_causal(self):
        q, k, v = (_rand(i, (2, 2, 32, 8)) for i in range(3))
        out = attention.mha(q, k, v, causal=False)
        want = ref.mha(q, k, v, causal=False)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_batch_heads(self):
        q, k, v = (_rand(i, (3, 4, 32, 16)) for i in range(3))
        out = attention.mha(q, k, v)
        want = ref.mha(q, k, v)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("bq,bk", [(16, 16), (16, 32), (32, 16), (64, 64)])
    def test_block_shape_invariance(self, bq, bk):
        """Output must not depend on the VMEM tiling choice."""
        q, k, v = (_rand(i, (1, 2, 64, 16)) for i in range(3))
        out = attention.mha(q, k, v, block_q=bq, block_k=bk)
        want = ref.mha(q, k, v)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_cross_attention_longer_k(self):
        q = _rand(0, (1, 2, 32, 16))
        k = _rand(1, (1, 2, 64, 16))
        v = _rand(2, (1, 2, 64, 16))
        out = attention.mha(q, k, v, causal=True)
        want = ref.mha(q, k, v, causal=True)
        np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)

    def test_rejects_nondividing_blocks(self):
        q, k, v = (_rand(i, (1, 1, 48, 8)) for i in range(3))
        with pytest.raises(ValueError):
            attention.mha(q, k, v, block_q=32, block_k=32)

    def test_scale_invariance_softmax(self):
        """Adding a constant to all logits (via huge v) must stay finite."""
        q = _rand(0, (1, 1, 32, 8)) * 100.0
        k = _rand(1, (1, 1, 32, 8)) * 100.0
        v = _rand(2, (1, 1, 32, 8))
        out = attention.mha(q, k, v)
        assert bool(jnp.all(jnp.isfinite(out)))

    @settings(max_examples=12, deadline=None)
    @given(
        t=st.sampled_from([16, 32, 64]),
        h=st.integers(1, 4),
        dh=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, t, h, dh, seed):
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, k, v = (jax.random.normal(kk, (1, h, t, dh)) for kk in keys)
        out = attention.mha(q, k, v, block_q=16, block_k=16)
        want = ref.mha(q, k, v)
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# PQC: gf2mm / vdecomp
# ---------------------------------------------------------------------------


class TestGf2:
    def test_gf2mm_matches_ref(self):
        key = jax.random.PRNGKey(0)
        a = jax.random.bernoulli(key, 0.5, (64, 64)).astype(jnp.int32)
        b = jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (64, 64)).astype(jnp.int32)
        np.testing.assert_array_equal(gf2.gf2mm(a, b), ref.gf2mm(a, b))

    def test_gf2mm_identity(self):
        eye = jnp.eye(32, dtype=jnp.int32)
        a = jax.random.bernoulli(jax.random.PRNGKey(2), 0.5, (32, 32)).astype(jnp.int32)
        np.testing.assert_array_equal(gf2.gf2mm(a, eye), a)

    def test_gf2mm_output_is_binary(self):
        a = jnp.ones((32, 32), jnp.int32)
        out = gf2.gf2mm(a, a)
        assert set(np.unique(np.asarray(out))).issubset({0, 1})

    @settings(max_examples=10, deadline=None)
    @given(
        m=st.sampled_from([16, 32, 64]),
        k=st.sampled_from([16, 32, 64]),
        n=st.sampled_from([16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_gf2mm_hypothesis(self, m, k, n, seed):
        ka, kb = jax.random.split(jax.random.PRNGKey(seed))
        a = jax.random.bernoulli(ka, 0.5, (m, k)).astype(jnp.int32)
        b = jax.random.bernoulli(kb, 0.5, (k, n)).astype(jnp.int32)
        np.testing.assert_array_equal(
            gf2.gf2mm(a, b, block_m=16, block_n=16, block_k=16), ref.gf2mm(a, b)
        )

    def test_vdecomp_matches_ref(self):
        words = jax.random.randint(jax.random.PRNGKey(3), (16,), 0, 2**31 - 1, jnp.int32)
        np.testing.assert_array_equal(gf2.vdecomp(words, 512), ref.vdecomp(words, 512))

    def test_vdecomp_roundtrip(self):
        """unpack(pack(bits)) == bits."""
        bits = jax.random.bernoulli(jax.random.PRNGKey(4), 0.5, (256,)).astype(jnp.int32)
        weights = (1 << jnp.arange(32)).astype(jnp.int32)
        words = jnp.sum(bits.reshape(-1, 32) * weights[None, :], axis=1, dtype=jnp.int32)
        np.testing.assert_array_equal(gf2.vdecomp(words, 256), bits)

    def test_vdecomp_rejects_bad_nbits(self):
        with pytest.raises(ValueError):
            gf2.vdecomp(jnp.zeros((4,), jnp.int32), 100)

    def test_syndrome_composition(self):
        """s = H · vdecomp(e_packed) end-to-end matches the oracle."""
        hkey, ekey = jax.random.split(jax.random.PRNGKey(5))
        h = jax.random.bernoulli(hkey, 0.3, (32, 128)).astype(jnp.int32)
        words = jax.random.randint(ekey, (4,), 0, 2**31 - 1, jnp.int32)
        e = gf2.vdecomp(words, 128)
        s = gf2.gf2mm(h, e[:, None], block_m=32, block_n=1, block_k=32)[:, 0]
        np.testing.assert_array_equal(s, ref.syndrome(h, ref.vdecomp(words, 128)))


# ---------------------------------------------------------------------------
# Point cloud: vdist3 / mcov / vfsmax / vmadot
# ---------------------------------------------------------------------------


class TestPointcloud:
    def test_vdist3(self):
        p, q = _rand(0, (256, 3)), _rand(1, (256, 3))
        np.testing.assert_allclose(
            pointcloud.vdist3(p, q), ref.vdist3(p, q), rtol=RTOL, atol=ATOL
        )

    def test_vdist3_zero_for_identical(self):
        p = _rand(0, (64, 3))
        np.testing.assert_allclose(pointcloud.vdist3(p, p), jnp.zeros(64), atol=ATOL)

    def test_mcov(self):
        p, q = _rand(2, (256, 3)), _rand(3, (256, 3))
        np.testing.assert_allclose(
            pointcloud.mcov(p, q), ref.mcov(p, q), rtol=1e-4, atol=1e-4
        )

    def test_mcov_translation_invariant(self):
        p, q = _rand(4, (128, 3)), _rand(5, (128, 3))
        shifted = pointcloud.mcov(p + 10.0, q - 5.0)
        np.testing.assert_allclose(shifted, pointcloud.mcov(p, q), rtol=1e-3, atol=1e-3)

    def test_vfsmax(self):
        x = _rand(6, (256,))
        mx, am = pointcloud.vfsmax(x)
        wmx, wam = ref.vfsmax(x)
        np.testing.assert_allclose(mx, wmx, rtol=RTOL)
        assert int(am) == int(wam)

    def test_vfsmax_finds_planted_max(self):
        x = _rand(7, (128,))
        x = x.at[77].set(1e9)
        mx, am = pointcloud.vfsmax(x)
        assert int(am) == 77 and float(mx) == pytest.approx(1e9)

    def test_vmadot(self):
        m, v = _rand(8, (64, 64)), _rand(9, (64,))
        np.testing.assert_allclose(
            pointcloud.vmadot(m, v), ref.vmadot(m, v), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from([64, 128, 256]), seed=st.integers(0, 2**16))
    def test_vdist3_hypothesis(self, n, seed):
        kp, kq = jax.random.split(jax.random.PRNGKey(seed))
        p = jax.random.normal(kp, (n, 3))
        q = jax.random.normal(kq, (n, 3))
        np.testing.assert_allclose(
            pointcloud.vdist3(p, q), ref.vdist3(p, q), rtol=1e-4, atol=1e-4
        )


# ---------------------------------------------------------------------------
# Graphics: phong / vrgb2yuv / vmvar
# ---------------------------------------------------------------------------


class TestGraphics:
    @staticmethod
    def _unit(key, n):
        v = _rand(key, (n, 3))
        return v / jnp.linalg.norm(v, axis=-1, keepdims=True)

    def test_phong(self):
        n, l, v = (self._unit(i, 256) for i in range(3))
        out = graphics.phong(n, l, v)
        want = ref.phong(n, l, v, 0.1, 0.7, 0.4, 16.0)
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)

    def test_phong_ambient_floor(self):
        """Facing-away normals still receive ambient light."""
        n = jnp.tile(jnp.array([[0.0, 0.0, 1.0]]), (64, 1))
        l = jnp.tile(jnp.array([[0.0, 0.0, -1.0]]), (64, 1))
        v = jnp.tile(jnp.array([[0.0, 0.0, 1.0]]), (64, 1))
        out = graphics.phong(n, l, v, ka=0.25)
        np.testing.assert_allclose(out, jnp.full(64, 0.25), atol=1e-5)

    def test_vrgb2yuv(self):
        rgb = jnp.abs(_rand(0, (256, 3)))
        np.testing.assert_allclose(
            graphics.vrgb2yuv(rgb), ref.vrgb2yuv(rgb), rtol=1e-4, atol=1e-4
        )

    def test_vrgb2yuv_grey_has_zero_chroma(self):
        grey = jnp.tile(jnp.array([[0.5, 0.5, 0.5]]), (64, 1))
        yuv = graphics.vrgb2yuv(grey)
        np.testing.assert_allclose(yuv[:, 1:], jnp.zeros((64, 2)), atol=1e-4)

    def test_vmvar(self):
        x = _rand(1, (64, 16))
        mean, var = graphics.vmvar(x)
        wm, wv = ref.vmvar(x)
        np.testing.assert_allclose(mean, wm, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(var, wv, rtol=1e-3, atol=1e-4)

    def test_vmvar_constant_rows(self):
        x = jnp.full((32, 8), 3.5)
        mean, var = graphics.vmvar(x)
        np.testing.assert_allclose(mean, jnp.full(32, 3.5), atol=1e-5)
        np.testing.assert_allclose(var, jnp.zeros(32), atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(
        n=st.sampled_from([64, 128]), w=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_vmvar_hypothesis(self, n, w, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (n, w))
        mean, var = graphics.vmvar(x)
        wm, wv = ref.vmvar(x)
        np.testing.assert_allclose(mean, wm, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(var, wv, rtol=1e-3, atol=1e-3)
