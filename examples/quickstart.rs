//! Quickstart: the full Aquas flow on one kernel in ~60 lines.
//!
//! 1. describe an ISAX at the functional Aquas-IR level,
//! 2. run interface-aware synthesis (§4.3) and look at the schedule,
//! 3. write the application loop and let the retargetable compiler (§5)
//!    offload it,
//! 4. compare cycle counts on the cycle-level core models.
//!
//! Run with: `cargo run --example quickstart`

use aquas::bench_harness::fir7;
use aquas::compiler::{compile, CompileOptions, IsaxDef};
use aquas::cores::rocket::{CoreConfig, RocketModel};
use aquas::cores::IsaxEngine;
use aquas::interface::model::InterfaceSet;
use aquas::ir::interp::Memory;
use aquas::synthesis::{hwgen, synthesize};
use aquas::workloads::pqc;

fn main() -> aquas::Result<()> {
    // --- hardware side: synthesize the vdecomp ISAX --------------------
    let itfcs = InterfaceSet::rocket_default();
    let isax_func = pqc::isax_vdecomp();
    let synth = synthesize(&isax_func, &itfcs, &Default::default())?;
    println!("synthesized `vdecomp`:");
    println!("  elided scratchpads: {:?}", synth.elided);
    println!("  schedule latency:   {} cycles", synth.schedule.mem_latency());
    for item in synth.schedule.items.iter().take(4) {
        println!(
            "    tag {} -> {} {}B (after {:?})",
            item.tag,
            itfcs.get(item.itfc).name,
            item.size,
            item.after
        );
    }
    let desc = hwgen::generate(&synth, &itfcs);
    let engine = IsaxEngine::from_synthesis(&synth, &desc, &itfcs);
    println!("  engine: {} cycles/invocation\n", engine.cycles_per_invocation());

    // --- software side: offload the application loop -------------------
    let software = pqc::software_vdecomp();
    let isax = IsaxDef { name: "vdecomp".into(), func: isax_func };
    let result = compile(&software, &[isax], &CompileOptions::default())?;
    println!("compiler matched: {:?}", result.stats.matched);
    println!(
        "  {} internal rewrites, {} external, e-nodes {} -> {}\n",
        result.stats.internal_rewrites,
        result.stats.external_rewrites,
        result.stats.initial_enodes,
        result.stats.saturated_enodes
    );

    // --- evaluation: base core vs ISAX-augmented core -------------------
    let base = RocketModel::new(CoreConfig::default());
    let mut mem = Memory::for_func(&software);
    let base_report = base.simulate(&software, &[], &mut mem)?;
    let acc = RocketModel::new(CoreConfig::default())
        .with_isax("vdecomp", engine.cycles_per_invocation());
    let mut mem2 = Memory::for_func(&result.func);
    let acc_report = acc.simulate(&result.func, &[], &mut mem2)?;
    println!("base core:   {} cycles", base_report.cycles);
    println!("with ISAX:   {} cycles", acc_report.cycles);
    println!("speedup:     {:.2}x", base_report.cycles as f64 / acc_report.cycles as f64);

    // --- bonus: the paper's fir7 walkthrough ----------------------------
    println!("\n(see `aquas synth --demo fir7` for the Figure 4 IR walkthrough)");
    let (smart, naive, _) = fir7::run();
    println!(
        "fir7 stage-in: naive {} cycles vs aquas {} cycles",
        naive.schedule.mem_latency(),
        smart.schedule.mem_latency()
    );
    Ok(())
}
