//! Graphics case study (§6.4): vmvar / mphong / vrgb2yuv against the
//! Saturn vector unit — the performance/area trade-off of Figure 7.
//!
//! Run with: `cargo run --example graphics_pipeline`

use aquas::area::AreaModel;
use aquas::bench_harness;
use aquas::ir::interp::{run as interp, Memory};
use aquas::runtime::{Runtime, Tensor};
use aquas::workloads::{graphics, Kernel};

fn main() -> aquas::Result<()> {
    // Render one "frame": phong shading then color conversion, through
    // the reference interpreter (numerics) + the fig7 harness (cycles).
    for k in graphics::kernels() {
        let mut mem = Memory::for_func(&k.software);
        (k.init)(&k.software, &mut mem);
        interp(&k.software, &[], &mut mem)?;
        let out = mem.read_f32(Kernel::buf(&k.software, k.outputs[0]));
        println!("{:>9}: out[0..4] = {:?}", k.name, &out[..4.min(out.len())]);
    }

    // Cross-check phong against the Pallas artifact.
    if let Ok(rt) = Runtime::load("artifacts") {
        let ks = graphics::kernels();
        let phong = ks.iter().find(|k| k.name == "mphong").unwrap();
        let mut mem = Memory::for_func(&phong.software);
        (phong.init)(&phong.software, &mut mem);
        interp(&phong.software, &[], &mut mem)?;
        let pad = |v: Vec<f32>| {
            let mut v = v;
            v.resize(256 * 3, 0.0);
            v
        };
        let n = pad(mem.read_f32(Kernel::buf(&phong.software, "nrm")));
        let l = pad(mem.read_f32(Kernel::buf(&phong.software, "lgt")));
        let v = pad(mem.read_f32(Kernel::buf(&phong.software, "view")));
        let out = rt.execute(
            "phong",
            &[
                Tensor::f32(n, &[256, 3])?,
                Tensor::f32(l, &[256, 3])?,
                Tensor::f32(v, &[256, 3])?,
            ],
        )?;
        let hw = out[0].as_f32()?;
        let sw = mem.read_f32(Kernel::buf(&phong.software, "inten"));
        for (i, (a, b)) in hw.iter().zip(&sw).enumerate() {
            assert!((a - b).abs() < 1e-3, "pixel {i}: {a} vs {b}");
        }
        println!("mphong datapath matches the Pallas golden model");
    }

    println!("\n{}", bench_harness::fig7().render());
    let area = AreaModel::default();
    println!(
        "saturn int-only still costs {:.1}% more area than Rocket; \
         Aquas stays in single digits per kernel",
        area.saturn_int_only().area_overhead_pct()
    );
    Ok(())
}
