//! PQC case study (§6.2): syndrome computation s = H·eᵀ over GF(2),
//! end-to-end — unpack the packed error bitstream (`vdecomp`), pack
//! requests, multiply (`mgf2mm`) — with both ISAXs offloaded by the
//! compiler, validated numerically against the scalar software AND the
//! AOT Pallas artifacts via PJRT.
//!
//! Run with: `cargo run --example pqc_syndrome` (needs `make artifacts`)

use aquas::bench_harness::table2;
use aquas::compiler::{compile, CompileOptions};
use aquas::ir::interp::{run as interp, Memory};
use aquas::runtime::{Runtime, Tensor};
use aquas::workloads::{pqc, Kernel};

fn main() -> aquas::Result<()> {
    // 1. Offload both kernels in the end-to-end program.
    let software = pqc::end_to_end_software();
    let kernels = pqc::kernels();
    let isaxes: Vec<_> = kernels.iter().map(|k| k.isax.clone()).collect();
    let compiled = compile(&software, &isaxes, &CompileOptions::default())?;
    println!("offloaded: {:?}", compiled.stats.matched);

    // 2. Numeric ground truth from the scalar software.
    let mut mem = Memory::for_func(&software);
    pqc::init_end_to_end(&software, &mut mem);
    interp(&software, &[], &mut mem)?;
    let syndrome = mem.read_i32(Kernel::buf(&software, "s"));
    println!("syndrome (first 16 bits): {:?}", &syndrome[..16]);

    // 3. Cross-check the vdecomp datapath against the AOT Pallas artifact.
    match Runtime::load("artifacts") {
        Ok(rt) => {
            let words = mem.read_i32(Kernel::buf(&software, "e"));
            let out = rt.execute("vdecomp", &[Tensor::i32(words, &[16])?])?;
            let bits = out[0].as_i32()?;
            let sw_bits = mem.read_i32(Kernel::buf(&software, "out"));
            assert_eq!(&bits[..sw_bits.len()], sw_bits.as_slice());
            println!("vdecomp datapath matches the Pallas golden model ({} bits)", bits.len());
        }
        Err(e) => println!("(skipping PJRT cross-check: {e})"),
    }

    // 4. Cycle-level comparison (the Table 2 PQC rows).
    let t = table2::run();
    for row in &t.pqc_rows {
        println!(
            "{:>10}: base {:>6} | aps {:>6} ({:.2}x) | aquas {:>6} ({:.2}x)",
            row.kernel.name,
            row.base_cycles,
            row.aps_cycles,
            row.aps_speedup(),
            row.aquas_cycles,
            row.aquas_speedup()
        );
    }
    let e = &t.pqc_e2e;
    println!(
        "{:>10}: base {:>6} | aps {:>6} ({:.2}x) | aquas {:>6} ({:.2}x)",
        "e2e", e.base_cycles, e.aps_cycles, e.aps_speedup(), e.aquas_cycles, e.aquas_speedup()
    );
    Ok(())
}
