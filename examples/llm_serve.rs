//! End-to-end driver (§6.5): serve batched LLM generation requests through
//! the full three-layer stack.
//!
//! - Layer 1/2 built the model: Pallas attention kernel inside a
//!   Llama-style transformer, AOT-lowered to `artifacts/*.hlo.txt`.
//! - Layer 3 (this binary): the serving coordinator — request router,
//!   KV-cache manager, prefill/decode scheduler — drives the compiled
//!   executables through PJRT. **No Python anywhere on this path.**
//!
//! Reports per-request TTFT/ITL in host wall-clock, aggregate throughput,
//! and the simulated-SoC speedup from the §6.5 cycle models (Figure 8),
//! plus a decode-first vs prefill-first scheduling ablation.
//!
//! Run with: `make artifacts && cargo run --release --example llm_serve`

use aquas::coordinator::{Coordinator, CoordinatorConfig, SchedulePolicy};
use aquas::runtime::Runtime;
use aquas::util::rng::Rng;
use aquas::util::stats::summarize;
use std::time::Instant;

fn main() -> aquas::Result<()> {
    let rt = Runtime::load("artifacts")?;
    let m = rt.manifest().model.clone();
    println!(
        "model: {} layers, dim {}, vocab {}, kv capacity {} (PJRT platform: {})",
        m.n_layers,
        m.dim,
        m.vocab,
        m.max_seq,
        rt.platform()
    );

    // Warm the executable cache so compile time doesn't pollute TTFT.
    rt.compile_entry("llm_prefill")?;
    rt.compile_entry("llm_decode")?;

    for policy in [SchedulePolicy::DecodeFirst, SchedulePolicy::PrefillFirst] {
        let mut coord = Coordinator::new(
            &rt,
            CoordinatorConfig { policy, max_active: 4, ..Default::default() },
        );
        // A small deterministic trace of 6 requests with varied prompts.
        let mut rng = Rng::new(42);
        let n_requests = 6;
        let new_tokens = 8;
        let t0 = Instant::now();
        for _ in 0..n_requests {
            let len = rng.range(4, m.prefill_len);
            let prompt: Vec<i32> =
                (0..len).map(|_| rng.below(m.vocab as u64) as i32).collect();
            coord.submit(prompt, new_tokens)?;
        }
        let metrics = coord.run_to_completion()?;
        let wall = t0.elapsed();

        let ttfts: Vec<f64> = metrics.iter().map(|m| m.ttft_us as f64 / 1000.0).collect();
        let itls: Vec<f64> = metrics
            .iter()
            .flat_map(|m| m.itl_us.iter().map(|&x| x as f64 / 1000.0))
            .collect();
        let total_tokens: usize = metrics.iter().map(|m| m.generated.len()).sum();
        let ttft = summarize(ttfts);
        let itl = summarize(itls);
        let sim_x: f64 = metrics.iter().map(|m| m.sim_base_cycles).sum::<f64>()
            / metrics.iter().map(|m| m.sim_isax_cycles).sum::<f64>();

        println!("\npolicy {policy:?}:");
        println!(
            "  {} requests, {} tokens in {:.1} ms -> {:.1} tok/s (host wall-clock)",
            metrics.len(),
            total_tokens,
            wall.as_secs_f64() * 1e3,
            total_tokens as f64 / wall.as_secs_f64()
        );
        println!(
            "  TTFT ms: mean {:.1} p50 {:.1} p95 {:.1} | ITL ms: mean {:.2} p50 {:.2} p95 {:.2}",
            ttft.mean, ttft.p50, ttft.p95, itl.mean, itl.p50, itl.p95
        );
        println!("  simulated SoC (110M int8 @80MHz): aquas/base speedup {sim_x:.2}x");
        for m in metrics.iter().take(2) {
            println!(
                "    req {}: prompt len {} -> generated {:?}",
                m.id, m.prompt_len, &m.generated
            );
        }
    }

    // Greedy decoding is deterministic: same prompt must reproduce.
    let mut c1 = Coordinator::new(&rt, CoordinatorConfig::default());
    c1.submit(vec![1, 2, 3, 4], 6)?;
    let g1 = c1.run_to_completion()?[0].generated.clone();
    let mut c2 = Coordinator::new(&rt, CoordinatorConfig::default());
    c2.submit(vec![1, 2, 3, 4], 6)?;
    let g2 = c2.run_to_completion()?[0].generated.clone();
    assert_eq!(g1, g2, "greedy decode must be deterministic");
    println!("\ndeterminism check passed: {g1:?}");
    Ok(())
}
