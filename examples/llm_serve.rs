//! End-to-end driver (§6.5): serve a batched LLM trace through the full
//! three-layer stack.
//!
//! - Layer 1/2 built the model: Pallas attention kernel inside a
//!   Llama-style transformer, AOT-lowered to `artifacts/*.hlo.txt`.
//! - Layer 3 (this binary): the paged-KV continuous-batching serving
//!   engine — request router, block allocator, prefill/decode scheduler —
//!   drives the compiled executables. **No Python anywhere on this path.**
//!
//! Replays one deterministic trace at batch widths 1 and 4 and across the
//! three scheduling policies, reporting TTFT / ITL percentiles and
//! aggregate throughput on the *modelled SoC clock* (the §6.5 cycle
//! models): the batch-1 run is the original single-stream coordinator,
//! and the batch-4 run shows the weight-stream amortization that paged-KV
//! batching buys on the same silicon.
//!
//! Run with: `cargo run --release --example llm_serve`

use aquas::coordinator::{Coordinator, CoordinatorConfig, SchedulePolicy, TraceSpec};
use aquas::runtime::Runtime;
use aquas::util::stats::summarize;

fn main() -> aquas::Result<()> {
    let rt = Runtime::load("artifacts")?;
    let m = rt.manifest().model.clone();
    println!(
        "model: {} layers, dim {}, vocab {}, kv capacity {} (platform: {})",
        m.n_layers, m.dim, m.vocab, m.max_seq, rt.platform()
    );

    // Saturating offered load so the batched runs measure amortization,
    // not idle gaps between arrivals.
    let spec = TraceSpec { n: 8, seed: 42, rate: 8.0, plen: (4, 12), gen: (6, 12) };
    let requests = spec.generate(m.vocab, m.prefill_len);

    let mut single_tok_s = 0.0;
    for (policy, batch) in [
        (SchedulePolicy::DecodeFirst, 1usize),
        (SchedulePolicy::DecodeFirst, 4),
        (SchedulePolicy::PrefillFirst, 4),
        (SchedulePolicy::Fair, 4),
    ] {
        let mut coord = Coordinator::new(
            &rt,
            CoordinatorConfig { policy, max_active: batch, ..Default::default() },
        );
        coord.submit_trace(&requests)?;
        let metrics = coord.run_to_completion()?;

        let ttft = summarize(metrics.iter().map(|m| m.ttft_us as f64 / 1e3).collect());
        let itl = summarize(
            metrics.iter().flat_map(|m| m.itl_us.iter().map(|&x| x as f64 / 1e3)).collect(),
        );
        let total_tokens: usize = metrics.iter().map(|m| m.generated.len()).sum();
        let elapsed_s = coord.sim_now_ms() / 1e3;
        let tok_s = total_tokens as f64 / elapsed_s;
        if batch == 1 {
            single_tok_s = tok_s;
        }
        let kv = coord.kv_stats();

        println!("\npolicy {policy:?}, batch {batch}:");
        println!(
            "  {} requests, {} tokens in {:.1} sim s -> {:.2} tok/s ({:.2}x single-stream)",
            metrics.len(),
            total_tokens,
            elapsed_s,
            tok_s,
            tok_s / single_tok_s,
        );
        println!(
            "  TTFT ms: p50 {:.0} p95 {:.0} | ITL ms: p50 {:.0} p95 {:.0} | \
             kv peak {} blocks | preemptions {} | leak-free {}",
            ttft.p50,
            ttft.p95,
            itl.p50,
            itl.p95,
            kv.peak_in_use,
            coord.preemptions(),
            kv.leak_free(),
        );
        for m in metrics.iter().take(2) {
            println!("    req {}: prompt len {} -> generated {:?}", m.id, m.prompt_len, &m.generated);
        }
    }

    // Greedy decoding is deterministic and batch-invariant: the whole
    // multi-request trace must produce identical per-request token
    // streams whether sequences run alone or share decode ticks.
    let replay = |batch: usize| -> aquas::Result<Vec<Vec<i32>>> {
        let mut c = Coordinator::new(
            &rt,
            CoordinatorConfig { max_active: batch, ..Default::default() },
        );
        c.submit_trace(&requests)?;
        Ok(c.run_to_completion()?.into_iter().map(|m| m.generated).collect())
    };
    let g1 = replay(1)?;
    let g4 = replay(4)?;
    assert_eq!(g1, g4, "greedy decode must be batch-invariant");
    println!("\ndeterminism check passed across batch widths ({} requests)", g1.len());
    Ok(())
}
