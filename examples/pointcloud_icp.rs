//! PCP case study (§6.3): one ICP-style registration iteration with the
//! four ISAXs (`vdist3.vv`, `mcov.vs`, `vfsmax`, `vmadot`) on the
//! 128-bit-bus configuration, cross-checked against the Pallas artifacts.
//!
//! Run with: `cargo run --example pointcloud_icp`

use aquas::bench_harness::table2;
use aquas::compiler::{compile, CompileOptions};
use aquas::ir::interp::{run as interp, Memory};
use aquas::runtime::{Runtime, Tensor};
use aquas::workloads::{pcp, Kernel};

fn main() -> aquas::Result<()> {
    let software = pcp::end_to_end_software();
    let kernels = pcp::kernels();
    let isaxes: Vec<_> = kernels.iter().map(|k| k.isax.clone()).collect();
    let compiled = compile(&software, &isaxes, &CompileOptions::default())?;
    println!("offloaded: {:?}", compiled.stats.matched);

    let mut mem = Memory::for_func(&software);
    pcp::init_end_to_end(&software, &mut mem);
    interp(&software, &[], &mut mem)?;
    let cov = mem.read_f32(Kernel::buf(&software, "cov"));
    let mx = mem.read_f32(Kernel::buf(&software, "mx"))[0];
    let am = mem.read_i32(Kernel::buf(&software, "am"))[0];
    println!("worst match: d²={mx:.3} at pair {am}");
    println!("cross-covariance: {:?}", &cov[..3]);

    // Cross-check vdist3 against the Pallas artifact (padded to its 256
    // pairs with zeros — zero rows produce zero distances).
    match Runtime::load("artifacts") {
        Ok(rt) => {
            let p = mem.read_f32(Kernel::buf(&software, "p"));
            let q = mem.read_f32(Kernel::buf(&software, "q"));
            let mut pp = p.clone();
            let mut qq = q.clone();
            pp.resize(256 * 3, 0.0);
            qq.resize(256 * 3, 0.0);
            let out = rt.execute(
                "vdist3",
                &[Tensor::f32(pp, &[256, 3])?, Tensor::f32(qq, &[256, 3])?],
            )?;
            let d_hw = out[0].as_f32()?;
            let d_sw = mem.read_f32(Kernel::buf(&software, "d"));
            for (i, (hw, sw)) in d_hw.iter().zip(&d_sw).enumerate() {
                assert!((hw - sw).abs() < 1e-3, "pair {i}: {hw} vs {sw}");
            }
            println!("vdist3 datapath matches the Pallas golden model");
        }
        Err(e) => println!("(skipping PJRT cross-check: {e})"),
    }

    let t = table2::run();
    for row in &t.pcp_rows {
        println!(
            "{:>10}: base {:>6} | aps {:>6} ({:.2}x) | aquas {:>6} ({:.2}x) | area +{:.1}%",
            row.kernel.name,
            row.base_cycles,
            row.aps_cycles,
            row.aps_speedup(),
            row.aquas_cycles,
            row.aquas_speedup(),
            row.area.area_overhead_pct()
        );
    }
    Ok(())
}
