# Convenience targets for the Aquas reproduction.
#
# `artifacts` requires a Python environment with JAX; everything else is
# pure Rust and works offline. The Rust runtime does NOT need the
# artifacts: without them it serves the built-in simulated manifest
# (rust/src/runtime/sim.rs), which is what CI exercises.

CARGO = cargo --manifest-path rust/Cargo.toml

.PHONY: build test bench artifacts pytest clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench:
	$(CARGO) bench --bench hotpath

# AOT-lower the L1/L2 Python stack to rust/artifacts/*.hlo.txt + a
# manifest.json. The output lands inside rust/ so both the integration
# tests (CARGO_MANIFEST_DIR/artifacts) and `cargo run` from rust/ pick it
# up; when present, the manifest's shapes drive the runtime's typechecks.
artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts

pytest:
	python -m pytest python/tests -q

clean:
	rm -rf rust/target rust/artifacts artifacts
